"""Plan-patch benchmark: incremental ShardPlan patching vs full recompile.

Section ``plan_patch_cells`` — the serving-side replan loop the incremental
plan pipeline closes: a GLAD-shaped layout over ``m`` servers absorbs a
sequence of small relayouts; each step measures

  * ``patch``   — :func:`repro.gnn.distributed.patch_plan` on the live plan
                  (dirty partitions only; measured on a throwaway deepcopy
                  so best-of-reps sees identical state),
  * ``compile`` — a from-scratch :func:`compile_plan` of the same new
                  assignment (what the pre-pipeline execution layer did
                  after every relayout),

interleaved in the same process/window (the only defensible protocol on a
±30%-noise box; see ROADMAP methodology notes).  Each cell also records
exact-parity counters — every patched plan is compared array-for-array
against a pinned fresh compile (``recompile_like``) — and the final
``halo_bytes_ppermute`` (integer, machine-independent), which the CI
parity gate pins: if the patch path ever drifts from the compile path,
the build fails.

A separate 8-host-device subprocess cell replays a move sequence through a
jitted ``make_bsp_forward`` and records the trace counts: value-only
patches must compile exactly once overall (zero retraces), the forced
capacity-growth step exactly once more.

Usage: PYTHONPATH=src python benchmarks/plan_patch.py [--quick] [--smoke]
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from repro.core.partition import partition_from_assign
from repro.gnn.distributed import (build_plan_bsr, compile_plan, patch_plan,
                                   plans_equal, recompile_like)
from repro.graphs.datagraph import synthetic_yelp


def _layout(n: int, parts: int, seed: int):
    """A clustered serving workload with a balanced locality layout.

    Yelp-shaped graph (the paper's second dataset: community cliques over
    contiguous ids) under contiguous balanced blocks — low cut, movers'
    neighborhoods span few servers, i.e. the regime a converged GLAD
    layout puts the serving path in.  (SIoT's preferential-attachment
    graph is an expander: NO layout has locality there, every mover's
    neighborhood spans all servers and the dirty set is the whole fleet —
    the ``scatter`` pattern below records that worst case honestly.)"""
    g = synthetic_yelp(n=n, target_links=int(1.2 * n), seed=seed + 1)
    assign = (np.arange(n, dtype=np.int64) * parts) // n
    return g, assign


def _move_sets(g, assign, parts, rng, steps, k, pattern):
    """Per-step mover sets.  ``local``: a BFS ball sheds to one target
    server (fault migration / GLAD-E slot shape — the serving regime);
    ``scatter``: k uniform vertices to uniform servers (worst case: the
    dirty set spans every partition)."""
    out = []
    cur = assign.copy()
    for _ in range(steps):
        new = cur.copy()
        if pattern == "scatter":
            movers = rng.choice(g.n, size=k, replace=False)
            new[movers] = rng.integers(0, parts, size=k)
        else:
            seed_v = int(rng.integers(0, g.n))
            ball, frontier = {seed_v}, [seed_v]
            while len(ball) < k and frontier:
                nxt = [u for v in frontier
                       for u in g.neighbors(v).tolist() if u not in ball]
                ball.update(nxt)
                frontier = nxt
            movers = np.array(sorted(ball))[:k]
            # Shed to the adjacent server — edge rebalancing moves load to
            # a NEARBY server (tau is distance-shaped), which also keeps
            # the ppermute schedule stable (no new shifts, no retrace).
            new[movers] = (int(cur[seed_v]) + 1) % parts
        out.append(new)
        cur = new
    return out


def run_patch_cell(n: int, parts: int, seed: int = 0, reps: int = 3,
                   steps: int = 8, movers: int = 8, pattern: str = "local",
                   bsr: bool = False) -> dict:
    g, assign = _layout(n, parts, seed)
    part = partition_from_assign(g, assign, parts, {})
    t0 = time.perf_counter()
    plan = compile_plan(g, part, slack=0.5)
    first_compile_s = time.perf_counter() - t0
    if bsr:
        build_plan_bsr(plan)

    rng = np.random.default_rng(seed + 1)
    assigns = _move_sets(g, assign, parts, rng, steps, movers, pattern)
    patch_ms, compile_ms, dirty_parts = [], [], []
    mismatches = grew_steps = 0
    for new in assigns:
        best_p = best_c = float("inf")
        for _r in range(reps):
            trial = copy.deepcopy(plan)          # identical state per rep
            t0 = time.perf_counter()
            patch_plan(trial, g, new)
            best_p = min(best_p, time.perf_counter() - t0)
            # The from-scratch path is what every caller ran before the
            # incremental pipeline: DevicePartition + plan (+ BSR retile).
            t0 = time.perf_counter()
            fresh = compile_plan(
                g, partition_from_assign(g, new, parts, {}))
            if bsr:
                build_plan_bsr(fresh)
            best_c = min(best_c, time.perf_counter() - t0)
        delta = patch_plan(plan, g, new)         # commit
        grew_steps += not delta.patched
        dirty_parts.append(len(delta.dirty_parts))
        if plans_equal(plan, recompile_like(plan, g, new)):
            mismatches += 1
        patch_ms.append(best_p * 1e3)
        compile_ms.append(best_c * 1e3)

    med_p = float(np.median(patch_ms))
    med_c = float(np.median(compile_ms))
    return {
        "n": n, "m": parts, "steps": steps, "moved_per_step": movers,
        "pattern": pattern, "bsr": bsr, "reps": reps,
        "first_compile_ms": round(first_compile_s * 1e3, 2),
        "patch_ms": round(med_p, 3), "compile_ms": round(med_c, 3),
        "patch_speedup": round(med_c / max(med_p, 1e-9), 2),
        "median_dirty_parts": float(np.median(dirty_parts)),
        "patch_parity_mismatches": mismatches,
        "grew_steps": grew_steps,
        "final_halo_rows": int(plan.halo_bytes_ppermute),
        "plan_version": int(plan.version),
    }


_RETRACE_SUBPROCESS = textwrap.dedent("""
    import os, json
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import numpy as np, jax, jax.numpy as jnp
    from repro.graphs import synthetic_siot
    from repro.gnn import (GNNConfig, init_params, compile_plan, patch_plan,
                           make_bsp_forward, scatter_features)
    from repro.core.partition import partition_from_assign
    from repro.jaxcompat import make_mesh

    rng = np.random.default_rng(0)
    g = synthetic_siot(n=240, target_links=700)
    assign = rng.integers(0, 8, size=g.n)
    plan = compile_plan(g, partition_from_assign(g, assign, 8, {}),
                        slack=0.5)
    mesh = make_mesh((8,), ('data',))
    cfg = GNNConfig('gcn', (52, 16, 2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    fwd = make_bsp_forward(cfg, plan, mesh)
    blocks = jnp.asarray(scatter_features(plan, g.features))
    fwd(params, blocks)
    cur, steps = assign, 6
    for _ in range(steps):
        movers = rng.choice(g.n, size=5, replace=False)
        new = cur.copy(); new[movers] = rng.integers(0, 8, size=5)
        patch_plan(plan, g, new)
        fwd(params, blocks)
        cur = new
    patch_traces = fwd.stats['traces']
    new = cur.copy(); new[: g.n // 2] = 0        # force capacity growth
    patch_plan(plan, g, new)
    fwd(params, jnp.asarray(scatter_features(plan, g.features)))
    print(json.dumps({"steps": steps,
                      "traces_after_patches": patch_traces,
                      "traces_after_growth": fwd.stats['traces']}))
""")


def run_retrace_cell() -> dict:
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _RETRACE_SUBPROCESS], env=env,
                       capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        return {"error": (r.stdout + r.stderr)[-2000:]}
    cell = json.loads(r.stdout.strip().splitlines()[-1])
    cell["zero_retrace_on_patch"] = cell["traces_after_patches"] == 1
    cell["single_retrace_on_growth"] = cell["traces_after_growth"] == 2
    return cell


def _merge(out_path: str, cells: list, retrace: dict) -> None:
    doc = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    doc["plan_patch_cells"] = cells
    doc["plan_patch_retrace"] = retrace
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"merged plan_patch_cells into {out_path}")


def _verify(cells: list, retrace: dict) -> list:
    bad = []
    for c in cells:
        if c.get("patch_parity_mismatches", 1) != 0:
            bad.append(f"n={c['n']} m={c['m']}: patched plan diverged from "
                       f"fresh compile on {c['patch_parity_mismatches']} "
                       f"steps")
    if "error" in retrace:
        bad.append(f"retrace cell failed: {retrace['error'][:300]}")
    elif not (retrace.get("zero_retrace_on_patch")
              and retrace.get("single_retrace_on_growth")):
        bad.append(f"retrace counts off: {retrace}")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small cell only (n=2k)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default="BENCH_layout.json")
    ap.add_argument("--fail-on-mismatch", action="store_true",
                    help="exit nonzero on patch/compile divergence or "
                         "unexpected retraces (the CI smoke gate)")
    args = ap.parse_args(argv)

    grid = [(2000, 8, "local", True)]
    if not args.quick:
        grid += [(20000, 32, "local", False), (20000, 32, "scatter", False),
                 (20000, 16, "local", False)]
    cells = []
    for n, m, pattern, bsr in grid:
        cell = run_patch_cell(n, m, reps=args.reps, pattern=pattern, bsr=bsr)
        cells.append(cell)
        print(f"n={n:>6} m={m:>2} {pattern:7s} bsr={int(bsr)}: patch "
              f"{cell['patch_ms']}ms vs compile {cell['compile_ms']}ms "
              f"-> {cell['patch_speedup']}x  (dirty "
              f"{cell['median_dirty_parts']:.0f}/{m}, parity mismatches "
              f"{cell['patch_parity_mismatches']}, grew "
              f"{cell['grew_steps']}/{cell['steps']})")
    retrace = run_retrace_cell()
    print(f"retrace cell: {retrace}")
    _merge(args.out, cells, retrace)

    if args.fail_on_mismatch:
        bad = _verify(cells, retrace)
        if bad:
            print("PLAN-PATCH GATE FAILURES:")
            for b in bad:
                print("  " + b)
            return 1
        print("plan-patch gate: parity exact, retrace counts as designed")
    return 0


def check_parity(ref_path: str = "BENCH_layout.json") -> int:
    """Re-run the quick cell and fail on drift vs the committed numbers.

    Gated quantities are integers and machine-independent: exact parity
    mismatch counts (must be 0) and the final ppermute traffic of the
    patched plan (pins the patch path's arithmetic, not wall time)."""
    with open(ref_path) as f:
        ref = json.load(f)
    ref_cells = {(c["n"], c["m"], c.get("pattern", "local")): c
                 for c in ref.get("plan_patch_cells", [])}
    if not ref_cells:
        print(f"no plan_patch_cells committed in {ref_path}; failing")
        return 1
    got = run_patch_cell(2000, 8, reps=1, pattern="local", bsr=True)
    bad = _verify([got], {"zero_retrace_on_patch": True,
                          "single_retrace_on_growth": True})
    r = ref_cells.get((2000, 8, "local"))
    if r is None:
        bad.append("committed file lacks the (n=2000, m=8) cell")
    elif got["final_halo_rows"] != r["final_halo_rows"]:
        bad.append(f"final_halo_rows {got['final_halo_rows']} != committed "
                   f"{r['final_halo_rows']} (patch-path drift)")
    if bad:
        print(f"PLAN-PATCH PARITY CHECK FAILED against {ref_path}")
        for b in bad:
            print("  " + b)
        return 1
    print(f"plan-patch parity OK vs {ref_path}")
    return 0


def run(full: bool = False, smoke: bool = False) -> int:
    argv = []
    if smoke or not full:
        argv.append("--quick")
    if smoke:
        argv += ["--reps", "1", "--out", "BENCH_layout.smoke.json",
                 "--fail-on-mismatch"]
    elif not full:
        argv += ["--out", "BENCH_layout.quick.json"]
    return main(argv)


if __name__ == "__main__":
    sys.exit(main())
