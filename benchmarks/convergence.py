"""Fig. 14/15: GLAD-S cost after every iteration (GraphSAGE over SIoT and
Yelp), varying the number of edge servers.  Demonstrates the exponential-
looking descent + marginal-decrement effect (submodularity)."""
from __future__ import annotations

from benchmarks.common import cost_model, dataset, emit, fleet
from repro.core.glad_s import glad_s


def run(full: bool = False, server_counts=(20, 40, 60), max_points=24):
    rows = []
    for ds in ("siot", "yelp"):
        g = dataset(ds, full)
        for m in server_counts:
            net = fleet(g, m)
            cm = cost_model(g, net, "sage", ds)
            res = glad_s(cm, R=3, seed=0)
            hist = res.history
            stride = max(1, len(hist) // max_points)
            for it in range(0, len(hist), stride):
                rows.append([ds, m, it, round(hist[it], 3)])
            rows.append([ds, m, len(hist) - 1, round(hist[-1], 3)])
    return emit(rows, ["dataset", "servers", "iteration", "cost"])


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
