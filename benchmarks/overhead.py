"""Fig. 17/18: scheduling overhead of GLAD-S vs GLAD-E under varying link
insertion percentages (SIoT and Yelp).  GLAD-E should be ~an order cheaper."""
from __future__ import annotations


from benchmarks.common import dataset, emit, fleet, timed
from repro.core import CostModel, workload_for
from repro.core.evolution import sample_delta, apply_delta
from repro.core.glad_e import glad_e
from repro.core.glad_s import glad_s


def run(full: bool = False, servers: int = 10,
        pcts=(0.01, 0.02, 0.04, 0.08, 0.16)):
    rows = []
    for ds in ("siot", "yelp"):
        g = dataset(ds, full)
        net = fleet(g, servers)
        in_dim = 52 if ds == "siot" else 100
        gnn = workload_for("gat", in_dim)
        cm = CostModel(net, g, gnn)
        base = glad_s(cm, R=3, seed=0)
        for pct in pcts:
            delta = sample_delta(g, pct_links=pct, seed=int(pct * 1000))
            # Only insertions stress the scheduler (paper Sec. VI-E).
            delta.del_edges = delta.del_edges[:0]
            g1 = apply_delta(g, delta)
            cm1 = CostModel(net, g1, gnn)
            res_s, t_s = timed(glad_s, cm1, R=3, seed=1)
            res_e, t_e = timed(glad_e, cm1, g, base.assign, seed=1)
            rows.append([ds, pct, round(t_s, 3), round(t_e, 3),
                         round(res_s.cost, 2), round(res_e.cost, 2)])
    return emit(rows, ["dataset", "pct_inserted", "glad_s_time_s",
                       "glad_e_time_s", "glad_s_cost", "glad_e_cost"])


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
