"""Layout-engine benchmark: incremental delta-cost engine vs the seed path,
plus the PR-2 block-diagonal round solver vs PR 1's batched sweep.

Section 1 (``cells``) — GLAD-S wall time and iterations/sec at n in
{1k, 5k, 20k} and m in {8, 16} on SIoT-shaped graphs, three paths, same
seeds:

  * ``seed``        — a vendored, faithful copy of the seed-commit Alg. 1
                      (full O(n+m) total() per proposal, dict/loop auxiliary
                      construction, Python residual BFS) — the baseline the
                      speedup is measured against.
  * ``incremental`` — repro.core.engine: cached delta-cost accept path,
                      vectorized auxiliary assembly, symmetric-CSR flow
                      solves, dirty-pair skipping.  Bit-identical trajectory.
  * ``batched``     — the incremental engine sweeping disjoint-pair
                      matchings per round (block-diagonal round solver).

Section 2 (``round_solver_cells``) — per-round wall clock of one full
round-robin pass from a fixed random init at n in {5k, 20k, 50k} and m in
{16, 32}, fresh engine per repetition, interleaved best-of-reps:

  * ``pairwise``    — PR 1's batched sweep semantics (one cut solve per
                      dirty pair) on the current engine.
  * ``block``       — the block-diagonal round solver (one glued flow pass
                      per round).
  * ``pr1``         — PR 1 as shipped (commit 5827408), i.e. WITHOUT this
                      PR's sorted-CSR datagraph / canonical-by-construction
                      assembly: measured with the same driver + methodology
                      on the same box and recorded as reference constants
                      below (the old code is not importable from this tree).

Full-run cost parity (sequential vs batched-pairwise vs batched-block,
exhaustive R) is recorded for n <= 20k; the 50k full runs are skipped by
default and logged as skipped — per-round numbers there come from the
first-pass measurement.

Emits BENCH_layout.json.

Usage: PYTHONPATH=src python benchmarks/layout_engine.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from collections import deque

import numpy as np

from repro.core.cost import CostModel, workload_for
from repro.core.glad_s import glad_s
from repro.graphs.datagraph import synthetic_siot
from repro.graphs.edgenet import build_edge_network

# --------------------------------------------------------------------------
# Vendored seed path (commit 112a22e), kept verbatim so the baseline cannot
# silently inherit engine-era optimizations.  Only the module plumbing
# (imports, names) is adapted.
# --------------------------------------------------------------------------
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import maximum_flow as _scipy_maxflow

_SCALE = 10 ** 7


def _seed_min_st_cut(n, s, t, edges_u, edges_v, caps_uv, caps_vu):
    """Seed-commit scipy path: COO build + Python residual BFS."""
    u = np.concatenate([edges_u, edges_v])
    v = np.concatenate([edges_v, edges_u])
    c = np.concatenate([caps_uv, caps_vu])
    keep = c > 0
    u, v, c = u[keep], v[keep], c[keep]
    cmax = float(c.max()) if len(c) else 1.0
    scale = _SCALE / max(cmax, 1e-30)
    ci = np.round(c * scale).astype(np.int64)
    ci = np.maximum(ci, 0)
    mat = csr_matrix((ci, (u, v)), shape=(n, n))
    mat.sum_duplicates()
    res = _scipy_maxflow(mat, s, t)
    residual = mat - res.flow
    side = np.zeros(n, dtype=bool)
    side[s] = True
    q = deque([s])
    indptr, indices, data = residual.indptr, residual.indices, residual.data
    while q:
        x = q.popleft()
        for k in range(indptr[x], indptr[x + 1]):
            y = indices[k]
            if data[k] > 0 and not side[y]:
                side[y] = True
                q.append(y)
    return res.flow_value / scale, side


def _seed_solve_pair(cm, assign, i, j):
    members = np.where((assign == i) | (assign == j))[0]
    if len(members) == 0:
        return None
    net, graph = cm.net, cm.graph
    n_aux = len(members) + 2
    S, T = len(members), len(members) + 1
    aux_id = {int(v): k for k, v in enumerate(members)}
    theta_i = cm.unary[members, i].astype(np.float64).copy()
    theta_j = cm.unary[members, j].astype(np.float64).copy()
    edges = graph.edges
    weights = graph.weights_or_ones()
    eu, ev = edges[:, 0], edges[:, 1]
    m_mask = np.zeros(graph.n, dtype=bool)
    m_mask[members] = True
    internal = m_mask[eu] & m_mask[ev]
    bnd_u = m_mask[eu] & ~m_mask[ev]
    bnd_v = ~m_mask[eu] & m_mask[ev]
    if bnd_u.any():
        ins, outs, w = eu[bnd_u], ev[bnd_u], weights[bnd_u]
        np.add.at(theta_i, [aux_id[int(x)] for x in ins],
                  net.tau[i, assign[outs]] * w)
        np.add.at(theta_j, [aux_id[int(x)] for x in ins],
                  net.tau[j, assign[outs]] * w)
    if bnd_v.any():
        ins, outs, w = ev[bnd_v], eu[bnd_v], weights[bnd_v]
        np.add.at(theta_i, [aux_id[int(x)] for x in ins],
                  net.tau[i, assign[outs]] * w)
        np.add.at(theta_j, [aux_id[int(x)] for x in ins],
                  net.tau[j, assign[outs]] * w)
    k = len(members)
    us = [S] * k + [kk for kk in range(k)]
    vs = list(range(k)) + [T] * k
    caps_uv = list(theta_j) + list(theta_i)
    caps_vu = [0.0] * (2 * k)
    if internal.any():
        tij = float(net.tau[i, j])
        for a, b, w in zip(eu[internal], ev[internal], weights[internal]):
            us.append(aux_id[int(a)])
            vs.append(aux_id[int(b)])
            caps_uv.append(tij * w)
            caps_vu.append(tij * w)
    _, side = _seed_min_st_cut(
        n_aux, S, T, np.array(us), np.array(vs),
        np.array(caps_uv), np.array(caps_vu))
    proposal = assign.copy()
    on_source = side[:k]
    proposal[members[on_source]] = i
    proposal[members[~on_source]] = j
    return proposal


def seed_glad_s(cm, R=None, seed=0, max_iterations=100_000):
    """Seed-commit Algorithm 1 driver (full total() on the accept path)."""
    rng = np.random.default_rng(seed)
    net, graph = cm.net, cm.graph
    t0 = time.perf_counter()
    assign = rng.integers(0, net.m, size=graph.n).astype(np.int64)
    pairs = net.pairs
    if R is None:
        R = net.m * (net.m - 1) // 2
    visits = np.zeros(len(pairs), dtype=np.int64)
    cur_cost = cm.total(assign)
    history = [cur_cost]
    r = iters = accepted = 0
    while r <= R and iters < max_iterations:
        mn = visits.min()
        cand = np.where(visits == mn)[0]
        p = cand[rng.integers(0, len(cand))]
        visits[p] += 1
        i, j = int(pairs[p, 0]), int(pairs[p, 1])
        proposal = _seed_solve_pair(cm, assign, i, j)
        iters += 1
        if proposal is not None:
            new_cost = cm.total(proposal)
            if new_cost < cur_cost - 1e-9:
                assign, cur_cost = proposal, new_cost
                accepted += 1
                r = 0
            else:
                r += 1
        else:
            r += 1
        history.append(cur_cost)
    return {
        "assign": assign, "cost": cur_cost, "iterations": iters,
        "accepted": accepted, "wall_time_s": time.perf_counter() - t0,
    }


# --------------------------------------------------------------------------
# PR 1 (commit 5827408) per-round reference, measured 2026-07-29 with the
# same first-pass/fresh-engine/interleaved-best-of-5 driver on the same box
# as the current numbers.  PR 1 predates the sorted-CSR datagraph and the
# canonical-by-construction flow assembly, so its per-pair sweep pays a
# lexsort per cut solve on top of the per-pair scipy fixed costs.
PR1_PER_ROUND_MS = {
    (5000, 16): 20.72,
    (5000, 32): 16.49,
    (20000, 16): 65.13,
    (20000, 32): 51.63,
    (50000, 16): 126.03,
    (50000, 32): 145.78,
}


def run_round_cell(n: int, m: int, seed: int = 0, reps: int = 3,
                   full_runs: bool = True, R=None):
    """Per-round wall clock of pairwise vs block round solving.

    One full pass over the round-robin schedule from a fixed random init,
    fresh engine per repetition so every rep does identical work;
    repetitions of the two solvers are interleaved and the per-solver MIN
    filters shared-box scheduler noise (PR-1 methodology).
    """
    from repro.core.engine import PairCutEngine, round_robin_rounds

    target_links = int(n * 4.2)
    g = synthetic_siot(n=n, target_links=target_links, seed=seed)
    net = build_edge_network(g, m, seed=seed)
    cm = CostModel(net, g, workload_for("gcn", 52))
    rng = np.random.default_rng(seed)
    init = rng.integers(0, m, size=n).astype(np.int64)
    connected = {(int(i), int(j)) for i, j in net.pairs}
    rounds = [[p for p in rnd if p in connected]
              for rnd in round_robin_rounds(m)]
    rounds = [r for r in rounds if r]

    def first_pass(solver):
        eng = PairCutEngine(cm, init)
        t0 = time.perf_counter()
        for rnd in rounds:
            eng.sweep_round(rnd, solver=solver)
        return time.perf_counter() - t0, eng.state.total

    solvers = ("pairwise", "block")
    for s in solvers:                                   # warmup
        first_pass(s)
    best = {s: float("inf") for s in solvers}
    pass_cost = {}
    for _ in range(max(1, reps)):
        for s in solvers:
            dt, c = first_pass(s)
            best[s] = min(best[s], dt)
            pass_cost[s] = c

    per_round = {s: best[s] / len(rounds) * 1000 for s in solvers}
    pr1_ms = PR1_PER_ROUND_MS.get((n, m))
    cell = {
        "n": n, "m": m, "rounds_per_pass": len(rounds),
        "pairwise_per_round_ms": round(per_round["pairwise"], 2),
        "block_per_round_ms": round(per_round["block"], 2),
        "pr1_per_round_ms": pr1_ms,
        "round_speedup_vs_pr1": (
            round(pr1_ms / per_round["block"], 2) if pr1_ms else None),
        "round_speedup_vs_pairwise": round(
            per_round["pairwise"] / per_round["block"], 2),
        "first_pass_rel_cost_err": abs(
            pass_cost["block"] - pass_cost["pairwise"]
        ) / max(abs(pass_cost["pairwise"]), 1e-12),
    }

    if full_runs:
        fns = {
            "sequential": lambda: glad_s(cm, R=R, seed=seed, sweep="single"),
            "batched_pairwise": lambda: glad_s(
                cm, R=R, seed=seed, sweep="batched",
                round_solver="pairwise"),
            "batched_block": lambda: glad_s(
                cm, R=R, seed=seed, sweep="batched", round_solver="block"),
        }
        wall = {k: float("inf") for k in fns}
        res = {}
        for _ in range(max(1, min(reps, 2))):
            for key, fn in fns.items():
                t0 = time.perf_counter()
                res[key] = fn()
                wall[key] = min(wall[key], time.perf_counter() - t0)
        pw, bl = res["batched_pairwise"], res["batched_block"]
        cell.update({
            "sequential_wall_s": round(wall["sequential"], 4),
            "batched_pairwise_wall_s": round(wall["batched_pairwise"], 4),
            "batched_block_wall_s": round(wall["batched_block"], 4),
            "sequential_cost": res["sequential"].cost,
            "batched_pairwise_cost": pw.cost,
            "batched_block_cost": bl.cost,
            "rel_cost_err_block_vs_pairwise": abs(bl.cost - pw.cost)
            / max(abs(pw.cost), 1e-12),
        })
    else:
        cell["full_runs"] = "skipped (n too large for the default budget)"
    return cell


def run_cell(n: int, m: int, seed: int = 0, R=None, reps: int = 3):
    target_links = int(n * 4.2)           # SIoT link density (33509/8001)
    g = synthetic_siot(n=n, target_links=target_links, seed=seed)
    net = build_edge_network(g, m, seed=seed)
    cm = CostModel(net, g, workload_for("gcn", 52))

    # Interleave the three paths' repetitions so shared-box scheduler noise
    # hits them alike; the runs are deterministic (identical work), so the
    # per-path MIN is the noise-filtered wall time.
    fns = {
        "seed": lambda: seed_glad_s(cm, R=R, seed=seed),
        "incremental": lambda: glad_s(cm, R=R, seed=seed),
        "batched": lambda: glad_s(cm, R=R, seed=seed, sweep="batched"),
    }
    best = {k: float("inf") for k in fns}
    out = {}
    for _ in range(max(1, reps)):
        for key, fn in fns.items():
            t0 = time.perf_counter()
            out[key] = fn()
            best[key] = min(best[key], time.perf_counter() - t0)
    sd, inc, bat = out["seed"], out["incremental"], out["batched"]
    sd["wall_time_s"] = best["seed"]
    t_inc, t_bat = best["incremental"], best["batched"]

    rel_inc = abs(inc.cost - sd["cost"]) / max(abs(sd["cost"]), 1e-12)
    rel_bat = abs(bat.cost - sd["cost"]) / max(abs(sd["cost"]), 1e-12)
    # Headline speedup: the fastest GLAD-S engine configuration whose final
    # cost matches the seed engine within 1e-6 relative (at the exhaustive-R
    # setting both the trajectory-identical single sweep and the batched
    # matching sweep converge to the seed's cost to ~1e-15).
    candidates = [
        (s, r)
        for s, r in ((sd["wall_time_s"] / t_inc, rel_inc),
                     (sd["wall_time_s"] / t_bat, rel_bat))
        if r < 1e-6
    ]
    if not candidates:   # no config matched the seed cost: report the
        candidates = [(sd["wall_time_s"] / t_inc, rel_inc)]  # mismatch
    speedup, rel = max(candidates)
    return {
        "n": n, "m": m, "R": "exhaustive" if R is None else R,
        "seed_wall_s": round(sd["wall_time_s"], 4),
        "incremental_wall_s": round(t_inc, 4),
        "batched_wall_s": round(t_bat, 4),
        "speedup": round(speedup, 2),
        "rel_cost_err": rel,
        "incremental_speedup": round(sd["wall_time_s"] / t_inc, 2),
        "batched_speedup": round(sd["wall_time_s"] / t_bat, 2),
        "seed_cost": sd["cost"],
        "incremental_cost": inc.cost,
        "batched_cost": bat.cost,
        "rel_cost_err_incremental": rel_inc,
        "rel_cost_err_batched": rel_bat,
        "iters_per_sec_seed": round(sd["iterations"] / sd["wall_time_s"], 1),
        "iters_per_sec_incremental": round(inc.iterations / t_inc, 1),
        "seed_iterations": sd["iterations"],
        "incremental_iterations": inc.iterations,
        "batched_iterations": bat.iterations,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: n=1k/5k engine cells, 5k round cells")
    ap.add_argument("--reps", type=int, default=3,
                    help="repetitions per path; min wall time is reported")
    ap.add_argument("--skip-seed-cells", action="store_true",
                    help="only the round-solver section (fast iteration)")
    ap.add_argument("--out", default="BENCH_layout.json")
    args = ap.parse_args(argv)

    cells = []
    if not args.skip_seed_cells:
        sizes = [1000, 5000] if args.quick else [1000, 5000, 20000]
        for n in sizes:
            for m in (8, 16):
                cell = run_cell(n, m, reps=args.reps)
                cells.append(cell)
                print(f"n={n:>6} m={m:>2}: seed {cell['seed_wall_s']:.2f}s "
                      f"incremental {cell['incremental_wall_s']:.2f}s "
                      f"({cell['incremental_speedup']}x) "
                      f"batched {cell['batched_wall_s']:.2f}s "
                      f"({cell['batched_speedup']}x) -> speedup "
                      f"{cell['speedup']}x rel_err {cell['rel_cost_err']:.2e}")

    round_grid = ([(5000, 16), (5000, 32)] if args.quick else
                  [(5000, 16), (5000, 32), (20000, 16), (20000, 32),
                   (50000, 16), (50000, 32)])
    round_cells = []
    for n, m in round_grid:
        full = n <= 20000
        if not full:
            print(f"n={n:>6} m={m:>2}: skipping full-convergence runs "
                  f"(per-round first-pass measurement only)")
        cell = run_round_cell(n, m, reps=args.reps, full_runs=full)
        round_cells.append(cell)
        print(f"n={n:>6} m={m:>2}: per-round pairwise "
              f"{cell['pairwise_per_round_ms']}ms block "
              f"{cell['block_per_round_ms']}ms pr1 "
              f"{cell['pr1_per_round_ms']}ms -> block vs pr1 "
              f"{cell['round_speedup_vs_pr1']}x, vs pairwise "
              f"{cell['round_speedup_vs_pairwise']}x")

    out = {
        "benchmark": "layout_engine",
        "graph": "synthetic_siot (links ~ 4.2n)",
        "workload": "gcn d=52",
        "R": "exhaustive |D|(|D|-1)/2",
        "methodology": "interleaved best-of-reps; round cells time one "
                       "full round-robin pass from a fixed random init "
                       "with a fresh engine per rep; pr1 reference "
                       "measured at commit 5827408 with the same driver",
        "pr1_reference_warning": "pr1_per_round_ms / round_speedup_vs_pr1 "
                                 "use vendored same-box constants "
                                 "(PR1_PER_ROUND_MS); rerunning on "
                                 "different hardware makes those ratios "
                                 "cross-machine — re-measure PR 1 at "
                                 "commit 5827408 before citing them",
        "cells": cells,
        "round_solver_cells": round_cells,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return 0


def run(full: bool = False, smoke: bool = False) -> None:
    """benchmarks.run entry point.

    The committed full-grid BENCH_layout.json is only (re)written by a
    ``--full`` section run or a direct ``python benchmarks/layout_engine.py``
    invocation; quick/smoke passes write side files so a plain
    ``python -m benchmarks.run`` cannot clobber the recorded numbers."""
    argv = []
    if smoke or not full:
        argv.append("--quick")
    if smoke:
        argv += ["--reps", "1", "--out", "BENCH_layout.smoke.json"]
    elif not full:
        argv += ["--out", "BENCH_layout.quick.json"]
    main(argv)


if __name__ == "__main__":
    sys.exit(main())
