"""Layout-engine benchmark: incremental delta-cost engine vs the seed path.

Measures GLAD-S wall time and iterations/sec at n in {1k, 5k, 20k} and
m in {8, 16} on SIoT-shaped graphs, comparing three paths on the same seeds:

  * ``seed``        — a vendored, faithful copy of the seed-commit Alg. 1
                      (full O(n+m) total() per proposal, dict/loop auxiliary
                      construction, Python residual BFS) — the baseline the
                      speedup is measured against.
  * ``incremental`` — repro.core.engine: cached delta-cost accept path,
                      vectorized auxiliary assembly, symmetric-CSR flow
                      solves, dirty-pair skipping.  Bit-identical trajectory.
  * ``batched``     — the incremental engine sweeping disjoint-pair
                      matchings per round.

Emits BENCH_layout.json.  Per cell: wall time of each path, the headline
``speedup`` (fastest GLAD-S engine configuration whose final cost matches
the seed engine within 1e-6 relative — both sweeps converge to the seed's
cost to ~1e-15 at exhaustive R), per-path speedups/costs, and iterations/s.

Usage: PYTHONPATH=src python benchmarks/layout_engine.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from collections import deque

import numpy as np

from repro.core.cost import CostModel, workload_for
from repro.core.glad_s import glad_s
from repro.graphs.datagraph import synthetic_siot
from repro.graphs.edgenet import build_edge_network

# --------------------------------------------------------------------------
# Vendored seed path (commit 112a22e), kept verbatim so the baseline cannot
# silently inherit engine-era optimizations.  Only the module plumbing
# (imports, names) is adapted.
# --------------------------------------------------------------------------
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import maximum_flow as _scipy_maxflow

_SCALE = 10 ** 7


def _seed_min_st_cut(n, s, t, edges_u, edges_v, caps_uv, caps_vu):
    """Seed-commit scipy path: COO build + Python residual BFS."""
    u = np.concatenate([edges_u, edges_v])
    v = np.concatenate([edges_v, edges_u])
    c = np.concatenate([caps_uv, caps_vu])
    keep = c > 0
    u, v, c = u[keep], v[keep], c[keep]
    cmax = float(c.max()) if len(c) else 1.0
    scale = _SCALE / max(cmax, 1e-30)
    ci = np.round(c * scale).astype(np.int64)
    ci = np.maximum(ci, 0)
    mat = csr_matrix((ci, (u, v)), shape=(n, n))
    mat.sum_duplicates()
    res = _scipy_maxflow(mat, s, t)
    residual = mat - res.flow
    side = np.zeros(n, dtype=bool)
    side[s] = True
    q = deque([s])
    indptr, indices, data = residual.indptr, residual.indices, residual.data
    while q:
        x = q.popleft()
        for k in range(indptr[x], indptr[x + 1]):
            y = indices[k]
            if data[k] > 0 and not side[y]:
                side[y] = True
                q.append(y)
    return res.flow_value / scale, side


def _seed_solve_pair(cm, assign, i, j):
    members = np.where((assign == i) | (assign == j))[0]
    if len(members) == 0:
        return None
    net, graph = cm.net, cm.graph
    n_aux = len(members) + 2
    S, T = len(members), len(members) + 1
    aux_id = {int(v): k for k, v in enumerate(members)}
    theta_i = cm.unary[members, i].astype(np.float64).copy()
    theta_j = cm.unary[members, j].astype(np.float64).copy()
    edges = graph.edges
    weights = graph.weights_or_ones()
    eu, ev = edges[:, 0], edges[:, 1]
    m_mask = np.zeros(graph.n, dtype=bool)
    m_mask[members] = True
    internal = m_mask[eu] & m_mask[ev]
    bnd_u = m_mask[eu] & ~m_mask[ev]
    bnd_v = ~m_mask[eu] & m_mask[ev]
    if bnd_u.any():
        ins, outs, w = eu[bnd_u], ev[bnd_u], weights[bnd_u]
        np.add.at(theta_i, [aux_id[int(x)] for x in ins],
                  net.tau[i, assign[outs]] * w)
        np.add.at(theta_j, [aux_id[int(x)] for x in ins],
                  net.tau[j, assign[outs]] * w)
    if bnd_v.any():
        ins, outs, w = ev[bnd_v], eu[bnd_v], weights[bnd_v]
        np.add.at(theta_i, [aux_id[int(x)] for x in ins],
                  net.tau[i, assign[outs]] * w)
        np.add.at(theta_j, [aux_id[int(x)] for x in ins],
                  net.tau[j, assign[outs]] * w)
    k = len(members)
    us = [S] * k + [kk for kk in range(k)]
    vs = list(range(k)) + [T] * k
    caps_uv = list(theta_j) + list(theta_i)
    caps_vu = [0.0] * (2 * k)
    if internal.any():
        tij = float(net.tau[i, j])
        for a, b, w in zip(eu[internal], ev[internal], weights[internal]):
            us.append(aux_id[int(a)])
            vs.append(aux_id[int(b)])
            caps_uv.append(tij * w)
            caps_vu.append(tij * w)
    _, side = _seed_min_st_cut(
        n_aux, S, T, np.array(us), np.array(vs),
        np.array(caps_uv), np.array(caps_vu))
    proposal = assign.copy()
    on_source = side[:k]
    proposal[members[on_source]] = i
    proposal[members[~on_source]] = j
    return proposal


def seed_glad_s(cm, R=None, seed=0, max_iterations=100_000):
    """Seed-commit Algorithm 1 driver (full total() on the accept path)."""
    rng = np.random.default_rng(seed)
    net, graph = cm.net, cm.graph
    t0 = time.perf_counter()
    assign = rng.integers(0, net.m, size=graph.n).astype(np.int64)
    pairs = net.pairs
    if R is None:
        R = net.m * (net.m - 1) // 2
    visits = np.zeros(len(pairs), dtype=np.int64)
    cur_cost = cm.total(assign)
    history = [cur_cost]
    r = iters = accepted = 0
    while r <= R and iters < max_iterations:
        mn = visits.min()
        cand = np.where(visits == mn)[0]
        p = cand[rng.integers(0, len(cand))]
        visits[p] += 1
        i, j = int(pairs[p, 0]), int(pairs[p, 1])
        proposal = _seed_solve_pair(cm, assign, i, j)
        iters += 1
        if proposal is not None:
            new_cost = cm.total(proposal)
            if new_cost < cur_cost - 1e-9:
                assign, cur_cost = proposal, new_cost
                accepted += 1
                r = 0
            else:
                r += 1
        else:
            r += 1
        history.append(cur_cost)
    return {
        "assign": assign, "cost": cur_cost, "iterations": iters,
        "accepted": accepted, "wall_time_s": time.perf_counter() - t0,
    }


# --------------------------------------------------------------------------
def run_cell(n: int, m: int, seed: int = 0, R=None, reps: int = 3):
    target_links = int(n * 4.2)           # SIoT link density (33509/8001)
    g = synthetic_siot(n=n, target_links=target_links, seed=seed)
    net = build_edge_network(g, m, seed=seed)
    cm = CostModel(net, g, workload_for("gcn", 52))

    # Interleave the three paths' repetitions so shared-box scheduler noise
    # hits them alike; the runs are deterministic (identical work), so the
    # per-path MIN is the noise-filtered wall time.
    fns = {
        "seed": lambda: seed_glad_s(cm, R=R, seed=seed),
        "incremental": lambda: glad_s(cm, R=R, seed=seed),
        "batched": lambda: glad_s(cm, R=R, seed=seed, sweep="batched"),
    }
    best = {k: float("inf") for k in fns}
    out = {}
    for _ in range(max(1, reps)):
        for key, fn in fns.items():
            t0 = time.perf_counter()
            out[key] = fn()
            best[key] = min(best[key], time.perf_counter() - t0)
    sd, inc, bat = out["seed"], out["incremental"], out["batched"]
    sd["wall_time_s"] = best["seed"]
    t_inc, t_bat = best["incremental"], best["batched"]

    rel_inc = abs(inc.cost - sd["cost"]) / max(abs(sd["cost"]), 1e-12)
    rel_bat = abs(bat.cost - sd["cost"]) / max(abs(sd["cost"]), 1e-12)
    # Headline speedup: the fastest GLAD-S engine configuration whose final
    # cost matches the seed engine within 1e-6 relative (at the exhaustive-R
    # setting both the trajectory-identical single sweep and the batched
    # matching sweep converge to the seed's cost to ~1e-15).
    candidates = [
        (s, r)
        for s, r in ((sd["wall_time_s"] / t_inc, rel_inc),
                     (sd["wall_time_s"] / t_bat, rel_bat))
        if r < 1e-6
    ]
    if not candidates:   # no config matched the seed cost: report the
        candidates = [(sd["wall_time_s"] / t_inc, rel_inc)]  # mismatch
    speedup, rel = max(candidates)
    return {
        "n": n, "m": m, "R": "exhaustive" if R is None else R,
        "seed_wall_s": round(sd["wall_time_s"], 4),
        "incremental_wall_s": round(t_inc, 4),
        "batched_wall_s": round(t_bat, 4),
        "speedup": round(speedup, 2),
        "rel_cost_err": rel,
        "incremental_speedup": round(sd["wall_time_s"] / t_inc, 2),
        "batched_speedup": round(sd["wall_time_s"] / t_bat, 2),
        "seed_cost": sd["cost"],
        "incremental_cost": inc.cost,
        "batched_cost": bat.cost,
        "rel_cost_err_incremental": rel_inc,
        "rel_cost_err_batched": rel_bat,
        "iters_per_sec_seed": round(sd["iterations"] / sd["wall_time_s"], 1),
        "iters_per_sec_incremental": round(inc.iterations / t_inc, 1),
        "seed_iterations": sd["iterations"],
        "incremental_iterations": inc.iterations,
        "batched_iterations": bat.iterations,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="n=1k/5k only (CI-sized)")
    ap.add_argument("--reps", type=int, default=3,
                    help="repetitions per path; min wall time is reported")
    ap.add_argument("--out", default="BENCH_layout.json")
    args = ap.parse_args(argv)

    sizes = [1000, 5000] if args.quick else [1000, 5000, 20000]
    cells = []
    for n in sizes:
        for m in (8, 16):
            cell = run_cell(n, m, reps=args.reps)
            cells.append(cell)
            print(f"n={n:>6} m={m:>2}: seed {cell['seed_wall_s']:.2f}s "
                  f"incremental {cell['incremental_wall_s']:.2f}s "
                  f"({cell['incremental_speedup']}x) "
                  f"batched {cell['batched_wall_s']:.2f}s "
                  f"({cell['batched_speedup']}x) -> speedup {cell['speedup']}x "
                  f"rel_err {cell['rel_cost_err']:.2e}")
    out = {
        "benchmark": "layout_engine",
        "graph": "synthetic_siot (links ~ 4.2n)",
        "workload": "gcn d=52",
        "R": "exhaustive |D|(|D|-1)/2",
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
