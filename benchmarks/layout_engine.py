"""Layout-engine benchmark: incremental delta-cost engine vs the seed path,
plus the PR-2 block-diagonal round solver vs PR 1's batched sweep.

Section 1 (``cells``) — GLAD-S wall time and iterations/sec at n in
{1k, 5k, 20k} and m in {8, 16} on SIoT-shaped graphs, three paths, same
seeds:

  * ``seed``        — a vendored, faithful copy of the seed-commit Alg. 1
                      (full O(n+m) total() per proposal, dict/loop auxiliary
                      construction, Python residual BFS) — the baseline the
                      speedup is measured against.
  * ``incremental`` — repro.core.engine: cached delta-cost accept path,
                      vectorized auxiliary assembly, symmetric-CSR flow
                      solves, dirty-pair skipping.  Bit-identical trajectory.
  * ``batched``     — the incremental engine sweeping disjoint-pair
                      matchings per round (block-diagonal round solver).

Section 2 (``round_solver_cells``) — per-round wall clock of one full
round-robin pass from a fixed random init at n in {5k, 20k, 50k} and m in
{16, 32}, fresh engine per repetition, interleaved best-of-reps:

  * ``pairwise``    — PR 1's batched sweep semantics (one cut solve per
                      dirty pair) on the current engine.
  * ``block``       — the block-diagonal round solver (glued flow passes,
                      member-budget grouping, persistency peel).
  * ``auto``        — the shipping default: scale-dependent solver choice
                      plus the 'auto' AssemblyCache policy.
  * ``cached``      — the block solver with the AssemblyCache forced on.
  * ``pr1``/``pr2`` — earlier PRs as shipped (commits 5827408 / 3c2dd42),
                      measured with the same driver + methodology and
                      recorded as reference constants below (the old code
                      is not importable from this tree).

Section 3 (``convergence_cells``) — per-round wall clock of full
convergence runs (repeated passes until none accepts): the steady-state
mix of assembly, churny mid-game solves and clean-skip tails, with final
costs checked against the recorded PR-2 trajectories.

Section 4 (``multilevel_cells``) — the multilevel V-cycle (heavy-edge
coarsening + per-level boundary refinement) vs the flat batched engine,
interleaved in the same noise window, at mu_factor=2.0 (the multi-server
regime; the default factors collapse these sizes onto one server, which
would make refinement vacuous).  Gates: final cost <= 1.05x flat,
coarsening determinism (cluster-map checksums reproduce on rebuild), and
the finest refinement replaying bit-identically on the flat engine from
the recorded projected init + boundary mask.  The full grid adds a
V-cycle-only n=500k scale cell (flat skipped by design).

Section 5 (``admission_cells``) — AssemblyCache pair-frequency admission
regression: a uniform pair scan over a starved byte budget must show ZERO
steady-state evictions (the second-touch gate freezes a resident set
instead of thrashing), nonzero rejected assemblies, nonzero hits, and
exact cost parity against a cache-free solve.

Section 6 (``session_cells``) — cross-slot persistent LayoutSession vs
per-slot rebuild, same-window interleaved A/B over two scenarios.
``fault_loop`` is the headline: an ElasticCoordinator straggler-flap
stream (hard degrades that migrate, mild flaps the relayout confirms
at zero moves) where the graph never changes, so adopted assemblies
column-patch and warm residuals repair across every event.  ``glad_a``
is the adaptive evolution loop (the examples/adaptive_relayout.py
workload) — recorded honestly: at scale it does NOT win (GLAD-E's
active masks make the members the changed region itself, so there is
nothing to carry; measured ~0.9x at n=20k), which is exactly the
cache='auto' policy's reasoning.  Only the per-event relayouts /
per-slot ``step()`` calls are timed; the arms must agree exactly on
per-event costs, migration counts and the final assignment (the
session may only change wall time, never bits).

Section 7 (``streamed_memory_cells``) — streamed vs in-core coarsening
peak RSS, one subprocess per arm (ru_maxrss is process-lifetime),
interleaved launches: same hierarchy bit-for-bit, bounded-window
transient footprint.  The n=500k cell gates the streamed arm at <= 60%
of the in-core peak.

Section 8 (``stack_reuse_cells``) — the persistent LevelStack over
repeated >50%-churn relayouts (the GLAD-E escalation regime): refresh
``acquire`` vs fresh ``build_levels`` per escalation (>= 1.3x gate),
with the session arm's relayout trajectories required to match the
fresh-build arm hex-for-hex.

Full-run cost parity (sequential vs batched-pairwise vs batched-block,
exhaustive R) is recorded for n <= 20k; the 50k full runs are skipped by
default and logged as skipped — per-round numbers there come from the
first-pass measurement.

Emits BENCH_layout.json.

Usage: PYTHONPATH=src python benchmarks/layout_engine.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from collections import deque

import numpy as np

from repro.core.cost import CostModel, workload_for
from repro.core.glad_s import glad_s
from repro.graphs.datagraph import synthetic_siot
from repro.graphs.edgenet import build_edge_network

# --------------------------------------------------------------------------
# Vendored seed path (commit 112a22e), kept verbatim so the baseline cannot
# silently inherit engine-era optimizations.  Only the module plumbing
# (imports, names) is adapted.
# --------------------------------------------------------------------------
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import maximum_flow as _scipy_maxflow

_SCALE = 10 ** 7


def _seed_min_st_cut(n, s, t, edges_u, edges_v, caps_uv, caps_vu):
    """Seed-commit scipy path: COO build + Python residual BFS."""
    u = np.concatenate([edges_u, edges_v])
    v = np.concatenate([edges_v, edges_u])
    c = np.concatenate([caps_uv, caps_vu])
    keep = c > 0
    u, v, c = u[keep], v[keep], c[keep]
    cmax = float(c.max()) if len(c) else 1.0
    scale = _SCALE / max(cmax, 1e-30)
    ci = np.round(c * scale).astype(np.int64)
    ci = np.maximum(ci, 0)
    mat = csr_matrix((ci, (u, v)), shape=(n, n))
    mat.sum_duplicates()
    res = _scipy_maxflow(mat, s, t)
    residual = mat - res.flow
    side = np.zeros(n, dtype=bool)
    side[s] = True
    q = deque([s])
    indptr, indices, data = residual.indptr, residual.indices, residual.data
    while q:
        x = q.popleft()
        for k in range(indptr[x], indptr[x + 1]):
            y = indices[k]
            if data[k] > 0 and not side[y]:
                side[y] = True
                q.append(y)
    return res.flow_value / scale, side


def _seed_solve_pair(cm, assign, i, j):
    members = np.where((assign == i) | (assign == j))[0]
    if len(members) == 0:
        return None
    net, graph = cm.net, cm.graph
    n_aux = len(members) + 2
    S, T = len(members), len(members) + 1
    aux_id = {int(v): k for k, v in enumerate(members)}
    theta_i = cm.unary[members, i].astype(np.float64).copy()
    theta_j = cm.unary[members, j].astype(np.float64).copy()
    edges = graph.edges
    weights = graph.weights_or_ones()
    eu, ev = edges[:, 0], edges[:, 1]
    m_mask = np.zeros(graph.n, dtype=bool)
    m_mask[members] = True
    internal = m_mask[eu] & m_mask[ev]
    bnd_u = m_mask[eu] & ~m_mask[ev]
    bnd_v = ~m_mask[eu] & m_mask[ev]
    if bnd_u.any():
        ins, outs, w = eu[bnd_u], ev[bnd_u], weights[bnd_u]
        np.add.at(theta_i, [aux_id[int(x)] for x in ins],
                  net.tau[i, assign[outs]] * w)
        np.add.at(theta_j, [aux_id[int(x)] for x in ins],
                  net.tau[j, assign[outs]] * w)
    if bnd_v.any():
        ins, outs, w = ev[bnd_v], eu[bnd_v], weights[bnd_v]
        np.add.at(theta_i, [aux_id[int(x)] for x in ins],
                  net.tau[i, assign[outs]] * w)
        np.add.at(theta_j, [aux_id[int(x)] for x in ins],
                  net.tau[j, assign[outs]] * w)
    k = len(members)
    us = [S] * k + [kk for kk in range(k)]
    vs = list(range(k)) + [T] * k
    caps_uv = list(theta_j) + list(theta_i)
    caps_vu = [0.0] * (2 * k)
    if internal.any():
        tij = float(net.tau[i, j])
        for a, b, w in zip(eu[internal], ev[internal], weights[internal]):
            us.append(aux_id[int(a)])
            vs.append(aux_id[int(b)])
            caps_uv.append(tij * w)
            caps_vu.append(tij * w)
    _, side = _seed_min_st_cut(
        n_aux, S, T, np.array(us), np.array(vs),
        np.array(caps_uv), np.array(caps_vu))
    proposal = assign.copy()
    on_source = side[:k]
    proposal[members[on_source]] = i
    proposal[members[~on_source]] = j
    return proposal


def seed_glad_s(cm, R=None, seed=0, max_iterations=100_000):
    """Seed-commit Algorithm 1 driver (full total() on the accept path)."""
    rng = np.random.default_rng(seed)
    net, graph = cm.net, cm.graph
    t0 = time.perf_counter()
    assign = rng.integers(0, net.m, size=graph.n).astype(np.int64)
    pairs = net.pairs
    if R is None:
        R = net.m * (net.m - 1) // 2
    visits = np.zeros(len(pairs), dtype=np.int64)
    cur_cost = cm.total(assign)
    history = [cur_cost]
    r = iters = accepted = 0
    while r <= R and iters < max_iterations:
        mn = visits.min()
        cand = np.where(visits == mn)[0]
        p = cand[rng.integers(0, len(cand))]
        visits[p] += 1
        i, j = int(pairs[p, 0]), int(pairs[p, 1])
        proposal = _seed_solve_pair(cm, assign, i, j)
        iters += 1
        if proposal is not None:
            new_cost = cm.total(proposal)
            if new_cost < cur_cost - 1e-9:
                assign, cur_cost = proposal, new_cost
                accepted += 1
                r = 0
            else:
                r += 1
        else:
            r += 1
        history.append(cur_cost)
    return {
        "assign": assign, "cost": cur_cost, "iterations": iters,
        "accepted": accepted, "wall_time_s": time.perf_counter() - t0,
    }


# --------------------------------------------------------------------------
# PR 1 (commit 5827408) per-round reference, measured with the same
# first-pass/fresh-engine/interleaved-best-of-reps driver on the PR-2 box.
# PR 1 predates the sorted-CSR datagraph and the canonical-by-construction
# flow assembly, so its per-pair sweep pays a lexsort per cut solve on top
# of the per-pair scipy fixed costs.  LEGACY: measured on the PR-2 box, not
# directly comparable to the PR-3 constants below.
PR1_PER_ROUND_MS = {
    (5000, 16): 20.72,
    (5000, 32): 16.49,
    (20000, 16): 65.13,
    (20000, 32): 51.63,
    (50000, 16): 126.03,
    (50000, 32): 145.78,
}

# PR 2 (commit 3c2dd42) block-solver reference, measured 2026-07-29 on the
# PR-3 box by running the PR-2 tree from a git worktree with the same
# drivers used for the current numbers, reps alternated between the two
# trees so shared-box noise hits both alike (per-tree MIN over 3 reps):
#   * first-pass per-round — one full round-robin pass from the fixed
#     random init, fresh engine per rep;
#   * convergence per-round — repeated full passes until a pass accepts
#     nothing, total wall / rounds executed (the steady-state mix of dirty
#     solves and clean skips).
PR2_PER_ROUND_MS = {
    (5000, 16): 11.29,
    (5000, 32): 10.42,
    (20000, 16): 37.28,
    (20000, 32): 28.87,
    (50000, 16): 93.44,
    (50000, 32): 77.05,
}
PR2_CONV_PER_ROUND_MS = {
    (5000, 16): 6.59,
    (5000, 32): 5.72,
    (20000, 16): 31.24,
    (20000, 32): 26.92,
    (50000, 16): 62.07,
    (50000, 32): 103.93,
}
# Final costs of the PR-2 convergence runs above — the current engine must
# reproduce them exactly (cache on or off), so every conv cell doubles as a
# cross-PR trajectory-parity check.
PR2_CONV_COST = {
    (5000, 16): 1938.91304508,
    (5000, 32): 1965.0499305,
    (20000, 16): 6995.80104532,
    (20000, 32): 7379.30227955,
    (50000, 16): 19053.5295312,
    (50000, 32): 17019.6993675,
}


# PR 3 (commit d9dfb92) converged-regime reference constants, measured
# 2026-07-29 on this box by running the PR-3 tree from a git worktree with
# the same resolve-cell driver, reps alternated between the trees (see
# --pr3-tree).  LEGACY fallback only — prefer the same-window subprocess.
PR3_RESOLVE_MS = {}

# Self-contained driver for measuring a REFERENCE git tree (a PR-2 or PR-3
# worktree) with the exact same methodology, launched as a subprocess right
# next to the local measurements so shared-box noise hits both in the same
# window — cross-window ratios against vendored constants are ±30% noise.
# Uses only API shared by every reference tree: PairCutEngine(cm, init)
# (engine defaults: cache/warm 'auto' resolve OFF for unmasked sweeps, so a
# reference tree measures its shipping cold path), LayoutState.commit and
# _mark_dirty (called manually when the tree predates the on_commit hook).
_REF_DRIVER = r"""
import sys, time
import numpy as np
tree, mode, n, m, reps = (sys.argv[1], sys.argv[2], int(sys.argv[3]),
                          int(sys.argv[4]), int(sys.argv[5]))
sys.path.insert(0, tree + "/src")
from repro.core.cost import CostModel, workload_for
from repro.core.engine import PairCutEngine, round_robin_rounds
from repro.graphs.datagraph import synthetic_siot
from repro.graphs.edgenet import build_edge_network
g = synthetic_siot(n=n, target_links=int(n * 4.2), seed=0)
net = build_edge_network(g, m, seed=0)
cm = CostModel(net, g, workload_for("gcn", 52))
cm.unary
rng = np.random.default_rng(0)
init = rng.integers(0, m, size=n).astype(np.int64)
connected = {(int(i), int(j)) for i, j in net.pairs}
rounds = [[p for p in rnd if p in connected]
          for rnd in round_robin_rounds(m)]
rounds = [r for r in rounds if r]
def converge(eng):
    nr = 0
    while True:
        acc = 0
        for rnd in rounds:
            nr += 1
            acc += sum(1 for _, ok in eng.sweep_round(rnd) if ok)
        if acc == 0:
            return nr
def first_run():
    eng = PairCutEngine(cm, init)
    t0 = time.perf_counter()
    for rnd in rounds:
        eng.sweep_round(rnd)
    return time.perf_counter() - t0, len(rounds), eng.state.total
def conv_run():
    eng = PairCutEngine(cm, init)
    t0 = time.perf_counter()
    nr = converge(eng)
    return time.perf_counter() - t0, nr, eng.state.total
if mode == "resolve":
    def reprobe_pass(eng):
        eng._version += 1
        eng._server_dirty[:] = eng._version
        t0 = time.perf_counter()
        for rnd in rounds:
            eng.sweep_round(rnd)
        return time.perf_counter() - t0
    eng = PairCutEngine(cm, init)
    converge(eng)
    reprobe_pass(eng)                      # untimed warmup, as local
    best_rp = float("inf")
    for _ in range(reps):
        best_rp = min(best_rp, reprobe_pass(eng))
    t0 = time.perf_counter()
    for ep in range(5):
        prng = np.random.default_rng(1000 + ep)
        mv = prng.choice(n, size=2, replace=False)
        ns = (eng.state.assign[mv] + prng.integers(1, m, size=2)) % m
        old = eng.state.assign[mv].copy()
        eng.state.commit(mv, ns)
        if getattr(eng.state, "on_commit", None) is None:
            eng._mark_dirty(mv, old)
        converge(eng)
    perturb = time.perf_counter() - t0
    print(best_rp * 1000, perturb / 5 * 1000, eng.state.total)
else:
    run = first_run if mode == "first" else conv_run
    run()
    best = float("inf")
    nr = cost = None
    for _ in range(reps):
        dt, nr, cost = run()
        best = min(best, dt)
    print(best / nr * 1000, cost)
"""


def _measure_ref_tree(tree: str, mode: str, n: int, m: int, reps: int):
    """Reference-tree measurement for one cell: ``(per_round_ms, cost)``
    for first/conv modes, ``(reprobe_ms, perturb_ms, cost)`` for resolve
    mode, or None if the subprocess fails (missing worktree, drift)."""
    import subprocess
    try:
        res = subprocess.run(
            [sys.executable, "-c", _REF_DRIVER, tree, mode,
             str(n), str(m), str(reps)],
            capture_output=True, text=True, timeout=3600, check=True)
        return tuple(float(x) for x in res.stdout.split())
    except Exception as exc:                    # pragma: no cover
        print(f"  (reference tree measurement failed: {exc})")
        return None


def run_round_cell(n: int, m: int, seed: int = 0, reps: int = 3,
                   full_runs: bool = True, R=None, ref_tree=None):
    """Per-round wall clock of pairwise vs block round solving.

    One full pass over the round-robin schedule from a fixed random init,
    fresh engine per repetition so every rep does identical work;
    repetitions of the two solvers are interleaved and the per-solver MIN
    filters shared-box scheduler noise (PR-1 methodology).
    """
    from repro.core.engine import PairCutEngine, round_robin_rounds

    target_links = int(n * 4.2)
    g = synthetic_siot(n=n, target_links=target_links, seed=seed)
    net = build_edge_network(g, m, seed=seed)
    cm = CostModel(net, g, workload_for("gcn", 52))
    rng = np.random.default_rng(seed)
    init = rng.integers(0, m, size=n).astype(np.int64)
    connected = {(int(i), int(j)) for i, j in net.pairs}
    rounds = [[p for p in rnd if p in connected]
              for rnd in round_robin_rounds(m)]
    rounds = [r for r in rounds if r]

    def first_pass(solver, **engine_kw):
        eng = PairCutEngine(cm, init, **engine_kw)
        t0 = time.perf_counter()
        for rnd in rounds:
            eng.sweep_round(rnd, solver=solver)
        return time.perf_counter() - t0, eng.state.total

    # 'auto' is the shipping default (scale-dependent solver + auto cache);
    # 'cached' forces the AssemblyCache on the block path; 'warm' adds the
    # warm-start incremental max-flow on top (first passes are its WORST
    # case — memberships churn, so its adaptive gates keep it on the cold
    # glued path — recorded so the gate's overhead stays visible).
    configs = {
        "pairwise": ("pairwise", {}),
        "block": ("block", {}),
        "auto": ("auto", {}),
        "cached": ("block", {"cache": True}),
        "warm": ("block", {"cache": True, "warm": True}),
    }
    for s, kw in configs.values():                      # warmup
        first_pass(s, **kw)
    best = {name: float("inf") for name in configs}
    pass_cost = {}
    for _ in range(max(1, reps)):
        for name, (s, kw) in configs.items():
            dt, c = first_pass(s, **kw)
            best[name] = min(best[name], dt)
            pass_cost[name] = c

    per_round = {name: best[name] / len(rounds) * 1000 for name in configs}
    pr1_ms = PR1_PER_ROUND_MS.get((n, m))
    pr2_ms = PR2_PER_ROUND_MS.get((n, m))
    pr2_src = "vendored (cross-window: +-30% box noise)"
    if ref_tree:
        ref = _measure_ref_tree(ref_tree, "first", n, m, reps)
        if ref is not None:
            pr2_ms = round(ref[0], 2)
            pr2_src = "same-window subprocess"
    costs = list(pass_cost.values())
    cell = {
        "n": n, "m": m, "rounds_per_pass": len(rounds),
        "pairwise_per_round_ms": round(per_round["pairwise"], 2),
        "block_per_round_ms": round(per_round["block"], 2),
        "auto_per_round_ms": round(per_round["auto"], 2),
        "cached_per_round_ms": round(per_round["cached"], 2),
        "warm_per_round_ms": round(per_round["warm"], 2),
        "first_pass_cost": pass_cost["auto"],
        "pr1_per_round_ms": pr1_ms,
        "pr2_per_round_ms": pr2_ms,
        "pr2_reference": pr2_src,
        "round_speedup_vs_pr1": (
            round(pr1_ms / per_round["block"], 2) if pr1_ms else None),
        "round_speedup_vs_pr2": (
            round(pr2_ms / per_round["auto"], 2) if pr2_ms else None),
        "cached_speedup_vs_pr2": (
            round(pr2_ms / per_round["cached"], 2) if pr2_ms else None),
        "round_speedup_vs_pairwise": round(
            per_round["pairwise"] / per_round["auto"], 2),
        "first_pass_rel_cost_err": (
            max(costs) - min(costs)) / max(abs(costs[0]), 1e-12),
    }

    if full_runs:
        fns = {
            "sequential": lambda: glad_s(cm, R=R, seed=seed, sweep="single"),
            "batched_pairwise": lambda: glad_s(
                cm, R=R, seed=seed, sweep="batched",
                round_solver="pairwise"),
            "batched_block": lambda: glad_s(
                cm, R=R, seed=seed, sweep="batched", round_solver="block"),
        }
        wall = {k: float("inf") for k in fns}
        res = {}
        for _ in range(max(1, min(reps, 2))):
            for key, fn in fns.items():
                t0 = time.perf_counter()
                res[key] = fn()
                wall[key] = min(wall[key], time.perf_counter() - t0)
        pw, bl = res["batched_pairwise"], res["batched_block"]
        cell.update({
            "sequential_wall_s": round(wall["sequential"], 4),
            "batched_pairwise_wall_s": round(wall["batched_pairwise"], 4),
            "batched_block_wall_s": round(wall["batched_block"], 4),
            "sequential_cost": res["sequential"].cost,
            "batched_pairwise_cost": pw.cost,
            "batched_block_cost": bl.cost,
            "rel_cost_err_block_vs_pairwise": abs(bl.cost - pw.cost)
            / max(abs(pw.cost), 1e-12),
        })
    else:
        cell["full_runs"] = "skipped (n too large for the default budget)"
    return cell


def run_resolve_cell(n: int, m: int, seed: int = 0, reps: int = 2,
                     ref_tree=None):
    """Converged-regime re-solve cell (the warm start's target regime).

    A fresh engine per configuration converges once (untimed), then two
    workloads are measured on the converged state:

      * **reprobe** — every pair forced dirty with no vertex touched (a
        control-plane revalidation sweep: fault detector wake-up, drift
        check).  One full round-robin pass, best of ``reps``.  Warm
        engines answer each solve from the retained residual with a
        mask-only BFS; cold engines re-push every flow.
      * **perturb** — five episodes of two externally-imposed vertex moves
        (deterministic sequence) each followed by re-convergence — the
        GraphEdge/Fograph-style dynamic re-optimization loop.

    Configurations: cold (shipping default for unmasked sweeps), cached
    (AssemblyCache only) and warm (cache + ResidualCut).  Final costs must
    agree EXACTLY across all three (recorded as rel errs).  ``ref_tree``
    re-measures a reference checkout with the identical driver in the same
    noise window (the perturbation sequence is deterministic and the
    trajectories bit-identical, so every tree does identical work)."""
    from repro.core.engine import PairCutEngine, round_robin_rounds

    target_links = int(n * 4.2)
    g = synthetic_siot(n=n, target_links=target_links, seed=seed)
    net = build_edge_network(g, m, seed=seed)
    cm = CostModel(net, g, workload_for("gcn", 52))
    cm.unary
    rng = np.random.default_rng(seed)
    init = rng.integers(0, m, size=n).astype(np.int64)
    connected = {(int(i), int(j)) for i, j in net.pairs}
    rounds = [[p for p in rnd if p in connected]
              for rnd in round_robin_rounds(m)]
    rounds = [r for r in rounds if r]

    def converge(eng):
        while True:
            acc = sum(1 for rnd in rounds
                      for _, ok in eng.sweep_round(rnd) if ok)
            if acc == 0:
                return

    def reprobe_pass(eng):
        eng._version += 1
        eng._server_dirty[:] = eng._version
        t0 = time.perf_counter()
        for rnd in rounds:
            eng.sweep_round(rnd)
        return time.perf_counter() - t0

    def measure(**engine_kw):
        eng = PairCutEngine(cm, init, **engine_kw)
        converge(eng)
        reprobe_pass(eng)          # untimed: primes warm state / caches,
        best_rp = float("inf")     # so every config's timed passes are
        for _ in range(max(1, reps)):          # its steady state
            best_rp = min(best_rp, reprobe_pass(eng))
        t0 = time.perf_counter()
        for ep in range(5):
            prng = np.random.default_rng(1000 + ep)
            mv = prng.choice(n, size=2, replace=False)
            ns = (eng.state.assign[mv] + prng.integers(1, m, size=2)) % m
            eng.apply_assignment(mv, ns)
            converge(eng)
        perturb = (time.perf_counter() - t0) / 5
        return best_rp * 1000, perturb * 1000, eng.state.total

    configs = {
        "cold": dict(cache=False, warm=False),
        "cached": dict(cache=True, warm=False),
        "warm": dict(cache=True, warm=True),
    }
    # No separate warmup pass: each measurement starts with its own full
    # (untimed) convergence, which warms every code path it then times.
    # The reference tree is measured INSIDE the same rep loop with the
    # same min-reduce, so both sides get best-of-identical-sample-counts
    # (an asymmetric protocol — local min-of-reps² vs ref min-of-reps —
    # would systematically inflate the vs-reference speedups).  Each ref
    # driver invocation mirrors one local measure() call exactly: untimed
    # warmup reprobe, best-of-``reps`` timed reprobes, one perturb run.
    out = {}
    ref = None
    ref_src = "none"
    for _ in range(max(1, reps)):
        for name, kw in configs.items():
            rp, pt, cost = measure(**kw)
            cur = out.get(name)
            out[name] = (min(rp, cur[0]) if cur else rp,
                         min(pt, cur[1]) if cur else pt, cost)
        if ref_tree:
            got = _measure_ref_tree(ref_tree, "resolve", n, m,
                                    max(1, reps))
            if got is not None:
                ref_src = "same-window subprocess"
                ref = (got if ref is None else
                       (min(ref[0], got[0]), min(ref[1], got[1]), got[2]))
    if ref is None and PR3_RESOLVE_MS.get((n, m)):      # pragma: no cover
        ref = PR3_RESOLVE_MS[(n, m)]
        ref_src = "vendored (cross-window: +-30% box noise)"
    cold, cached, warm = out["cold"], out["cached"], out["warm"]
    cell = {
        "n": n, "m": m,
        "reprobe_cold_ms": round(cold[0], 2),
        "reprobe_cached_ms": round(cached[0], 2),
        "reprobe_warm_ms": round(warm[0], 2),
        "perturb_cold_ms": round(cold[1], 2),
        "perturb_cached_ms": round(cached[1], 2),
        "perturb_warm_ms": round(warm[1], 2),
        "warm_reprobe_speedup_vs_cold": round(cold[0] / warm[0], 2),
        "warm_perturb_speedup_vs_cached": round(cached[1] / warm[1], 2),
        "resolve_final_cost": cold[2],
        "rel_cost_err_cached_vs_cold": abs(cached[2] - cold[2])
        / max(abs(cold[2]), 1e-12),
        "rel_cost_err_warm_vs_cold": abs(warm[2] - cold[2])
        / max(abs(cold[2]), 1e-12),
        "pr3_reference": ref_src,
    }
    if ref is not None:
        cell.update({
            "pr3_reprobe_ms": round(ref[0], 2),
            "pr3_perturb_ms": round(ref[1], 2),
            "warm_reprobe_speedup_vs_pr3": round(ref[0] / warm[0], 2),
            "warm_perturb_speedup_vs_pr3": round(ref[1] / warm[1], 2),
            "rel_cost_err_vs_pr3": abs(cold[2] - ref[2])
            / max(abs(ref[2]), 1e-12),
        })
    return cell


def run_conv_cell(n: int, m: int, seed: int = 0, reps: int = 2,
                  ref_tree=None):
    """Convergence-run per-round wall clock: repeated full round-robin
    passes until a pass accepts nothing (the steady-state mix of first-pass
    assembly, mid-run churn and clean-skip tails), fresh engine per rep.
    Compares the shipping defaults and the forced-cache configuration
    against the PR-2 block solver measured with the identical driver, and
    checks the final cost against the recorded PR-2 trajectory."""
    from repro.core.engine import PairCutEngine, round_robin_rounds

    target_links = int(n * 4.2)
    g = synthetic_siot(n=n, target_links=target_links, seed=seed)
    net = build_edge_network(g, m, seed=seed)
    cm = CostModel(net, g, workload_for("gcn", 52))
    rng = np.random.default_rng(seed)
    init = rng.integers(0, m, size=n).astype(np.int64)
    connected = {(int(i), int(j)) for i, j in net.pairs}
    rounds = [[p for p in rnd if p in connected]
              for rnd in round_robin_rounds(m)]
    rounds = [r for r in rounds if r]

    def converge(**engine_kw):
        eng = PairCutEngine(cm, init, **engine_kw)
        t0 = time.perf_counter()
        nr = 0
        while True:
            accepts = 0
            for rnd in rounds:
                nr += 1
                accepts += sum(
                    1 for _, ok in eng.sweep_round(rnd) if ok)
            if accepts == 0:
                break
        return time.perf_counter() - t0, nr, eng.state.total

    configs = {"default": {}, "cached": {"cache": True},
               "warm": {"cache": True, "warm": True}}
    for kw in configs.values():                         # warmup
        converge(**kw)
    best = {name: float("inf") for name in configs}
    info = {}
    for _ in range(max(1, reps)):
        for name, kw in configs.items():
            dt, nr, c = converge(**kw)
            best[name] = min(best[name], dt)
            info[name] = (nr, c)
    pr2_ms = PR2_CONV_PER_ROUND_MS.get((n, m))
    pr2_cost = PR2_CONV_COST.get((n, m))
    pr2_src = "vendored (cross-window: +-30% box noise)"
    if ref_tree:
        ref = _measure_ref_tree(ref_tree, "conv", n, m, reps)
        if ref is not None:
            pr2_ms = round(ref[0], 2)
            pr2_cost = ref[1]
            pr2_src = "same-window subprocess"
    per_round = {name: best[name] / info[name][0] * 1000
                 for name in configs}
    cost = info["default"][1]
    return {
        "n": n, "m": m, "rounds_to_converge": info["default"][0],
        "pr2_reference": pr2_src,
        "default_per_round_ms": round(per_round["default"], 2),
        "cached_per_round_ms": round(per_round["cached"], 2),
        "warm_per_round_ms": round(per_round["warm"], 2),
        "pr2_per_round_ms": pr2_ms,
        "conv_speedup_vs_pr2": (
            round(pr2_ms / per_round["default"], 2) if pr2_ms else None),
        "final_cost": cost,
        "cached_rel_cost_err": abs(info["cached"][1] - cost)
        / max(abs(cost), 1e-12),
        "warm_rel_cost_err": abs(info["warm"][1] - cost)
        / max(abs(cost), 1e-12),
        "rel_cost_err_vs_pr2": (
            abs(cost - pr2_cost) / max(abs(pr2_cost), 1e-12)
            if pr2_cost else None),
    }


def run_cell(n: int, m: int, seed: int = 0, R=None, reps: int = 3):
    target_links = int(n * 4.2)           # SIoT link density (33509/8001)
    g = synthetic_siot(n=n, target_links=target_links, seed=seed)
    net = build_edge_network(g, m, seed=seed)
    cm = CostModel(net, g, workload_for("gcn", 52))

    # Interleave the three paths' repetitions so shared-box scheduler noise
    # hits them alike; the runs are deterministic (identical work), so the
    # per-path MIN is the noise-filtered wall time.
    fns = {
        "seed": lambda: seed_glad_s(cm, R=R, seed=seed),
        "incremental": lambda: glad_s(cm, R=R, seed=seed),
        "batched": lambda: glad_s(cm, R=R, seed=seed, sweep="batched"),
    }
    best = {k: float("inf") for k in fns}
    out = {}
    for _ in range(max(1, reps)):
        for key, fn in fns.items():
            t0 = time.perf_counter()
            out[key] = fn()
            best[key] = min(best[key], time.perf_counter() - t0)
    sd, inc, bat = out["seed"], out["incremental"], out["batched"]
    sd["wall_time_s"] = best["seed"]
    t_inc, t_bat = best["incremental"], best["batched"]

    rel_inc = abs(inc.cost - sd["cost"]) / max(abs(sd["cost"]), 1e-12)
    rel_bat = abs(bat.cost - sd["cost"]) / max(abs(sd["cost"]), 1e-12)
    # Headline speedup: the fastest GLAD-S engine configuration whose final
    # cost matches the seed engine within 1e-6 relative (at the exhaustive-R
    # setting both the trajectory-identical single sweep and the batched
    # matching sweep converge to the seed's cost to ~1e-15).
    candidates = [
        (s, r)
        for s, r in ((sd["wall_time_s"] / t_inc, rel_inc),
                     (sd["wall_time_s"] / t_bat, rel_bat))
        if r < 1e-6
    ]
    if not candidates:   # no config matched the seed cost: report the
        candidates = [(sd["wall_time_s"] / t_inc, rel_inc)]  # mismatch
    speedup, rel = max(candidates)
    return {
        "n": n, "m": m, "R": "exhaustive" if R is None else R,
        "seed_wall_s": round(sd["wall_time_s"], 4),
        "incremental_wall_s": round(t_inc, 4),
        "batched_wall_s": round(t_bat, 4),
        "speedup": round(speedup, 2),
        "rel_cost_err": rel,
        "incremental_speedup": round(sd["wall_time_s"] / t_inc, 2),
        "batched_speedup": round(sd["wall_time_s"] / t_bat, 2),
        "seed_cost": sd["cost"],
        "incremental_cost": inc.cost,
        "batched_cost": bat.cost,
        "rel_cost_err_incremental": rel_inc,
        "rel_cost_err_batched": rel_bat,
        "iters_per_sec_seed": round(sd["iterations"] / sd["wall_time_s"], 1),
        "iters_per_sec_incremental": round(inc.iterations / t_inc, 1),
        "seed_iterations": sd["iterations"],
        "incremental_iterations": inc.iterations,
        "batched_iterations": bat.iterations,
    }


def _level_checksums(stack):
    """Splitmix-mixed XOR checksum per coarsening rung (cluster maps)."""
    return [int(np.bitwise_xor.reduce(
        (lvl.cluster_of.astype(np.uint64)
         * np.uint64(0x9E3779B97F4A7C15))
        ^ np.arange(len(lvl.cluster_of), dtype=np.uint64)))
        for lvl in stack[1:]]


def run_multilevel_cell(n: int, m: int, seed: int = 0, reps: int = 2,
                        mu_factor: float = 2.0, coarsen_to=None,
                        run_flat: bool = True, chunk_vertices=None,
                        record_levels: bool = True, check_streamed=None):
    """Multilevel V-cycle vs the flat batched engine, interleaved in the
    same noise window.

    ``mu_factor=2.0`` (vs the 0.05 default of the other sections) puts the
    instances in the multi-server regime: at the default factors the
    optimum collapses onto one server at these sizes, which would make the
    boundary refinement vacuous and the cost-ratio gate meaningless.

    Records the quality gate (multilevel cost / flat cost), the coarsening
    hierarchy with a determinism checksum (matching is a pure function of
    the cost model), and a bit-identity flag for replaying the finest
    refinement on the flat engine from the recorded projected init +
    boundary mask.  ``run_flat=False`` marks the flat run skipped (the
    n >= 500k memory/runtime cell: the V-cycle must complete, the flat
    engine need not).

    ``chunk_vertices`` streams the timed V-cycle's coarsening (the scale
    cells run streamed: bit-identical by contract, bounded-window RSS);
    ``record_levels=False`` slims the per-level replay telemetry to
    checksums (the finest-replay gate is skipped — nothing to replay
    from).  ``check_streamed`` (default: on for n <= 50k) additionally
    gates streamed-vs-in-core bit-identity INSIDE the cell: the streamed
    hierarchy must equal the in-core one rung-for-rung, and a streamed
    V-cycle must reproduce the in-core V-cycle's cost hex and assignment
    exactly — this is the --smoke/--fail-on-mismatch streamed parity
    gate."""
    import resource

    from repro.core.multilevel import COARSEN_TO, build_levels

    if coarsen_to is None:
        coarsen_to = COARSEN_TO
    if check_streamed is None:
        check_streamed = n <= 50_000
    target_links = int(n * 4.2)
    g = synthetic_siot(n=n, target_links=target_links, seed=seed)
    net = build_edge_network(g, m, seed=seed, mu_factor=mu_factor)
    cm = CostModel(net, g, workload_for("gcn", 52))

    fns = {"multilevel": lambda: glad_s(cm, seed=seed, sweep="batched",
                                        multilevel=True,
                                        coarsen_to=coarsen_to,
                                        chunk_vertices=chunk_vertices,
                                        record_levels=record_levels)}
    if run_flat:
        fns["flat"] = lambda: glad_s(cm, seed=seed, sweep="batched")
    best = {k: float("inf") for k in fns}
    out = {}
    for _ in range(max(1, reps)):
        for key, fn in fns.items():
            t0 = time.perf_counter()
            out[key] = fn()
            best[key] = min(best[key], time.perf_counter() - t0)
    ml = out["multilevel"]

    # Coarsening determinism: rebuilding the hierarchy must reproduce every
    # cluster map bit-for-bit.  Scale cells rebuild through the same
    # streamed path they were timed on (the in-core rebuild is exactly the
    # O(n+m)-per-level materialization the cell exists to avoid).
    def checksums():
        return _level_checksums(build_levels(cm, coarsen_to=coarsen_to,
                                             chunk_vertices=chunk_vertices))

    cks = checksums()
    deterministic = cks == checksums()

    # Finest refinement == flat engine: replay from the recorded projected
    # init + boundary mask and compare the history hex-for-hex.  Slimmed
    # telemetry (record_levels=False) keeps only checksums of those
    # arrays — nothing to replay from, so the gate is marked skipped
    # rather than vacuously passed.
    finest = ml.levels[-1]
    replay_ok = None
    finest_iters = finest.get("iterations", 0)
    if record_levels:
        if finest["role"] == "refine" and finest["active"] is not None \
                and finest["active"].any():
            replay = glad_s(cm, R=finest["R"], init=finest["init"],
                            active=finest["active"], seed=seed,
                            sweep="batched")
            replay_ok = (
                [np.float64(h).hex() for h in replay.history]
                == [np.float64(h).hex() for h in finest["history"]]
                and bool((replay.assign == ml.assign).all()))
            finest_iters = finest["iterations"]
        else:           # projection had no cut links: nothing to replay
            replay_ok = True
            finest_iters = 0

    cell = {
        "n": n, "m": m, "mu_factor": mu_factor, "coarsen_to": coarsen_to,
        "chunk_vertices": chunk_vertices,
        "record_levels": record_levels,
        "levels": len(ml.levels),
        "level_sizes": [ls["n"] for ls in ml.levels[::-1]],
        "coarsest_n": ml.levels[0]["n"],
        "coarsest_wall_s": round(ml.levels[0]["wall_time_s"], 4),
        "multilevel_wall_s": round(best["multilevel"], 4),
        "multilevel_cost": ml.cost,
        "multilevel_iterations": ml.iterations,
        "finest_refine_iterations": finest_iters,
        "coarsening_deterministic": deterministic,
        "cluster_checksum": cks[0] if cks else None,
        "max_rss_gb": round(resource.getrusage(
            resource.RUSAGE_SELF).ru_maxrss / 1e6, 3),
    }
    if replay_ok is None:
        cell["finest_replay"] = ("skipped (record_levels=False scale "
                                 "cell: replay arrays slimmed to "
                                 "checksums)")
    else:
        cell["finest_replay_bit_identical"] = replay_ok

    if check_streamed:
        from repro.core.multilevel_stream import AUTO_CHUNK_VERTICES
        incore = build_levels(cm, coarsen_to=coarsen_to)
        incore_cks = _level_checksums(incore)
        # A deliberately awkward odd chunk (splits matched pairs across
        # window boundaries) plus the shipping auto default.
        chunks = [191, AUTO_CHUNK_VERTICES]
        incore_sizes = [lvl.cm.graph.n for lvl in incore]
        levels_ok = True
        for c in chunks:
            got = build_levels(cm, coarsen_to=coarsen_to, chunk_vertices=c)
            levels_ok &= (_level_checksums(got) == incore_cks
                          and [lvl.cm.graph.n for lvl in got]
                          == incore_sizes)
        sml = glad_s(cm, seed=seed, sweep="batched", multilevel=True,
                     coarsen_to=coarsen_to, chunk_vertices=chunks[0])
        vcycle_ok = (np.float64(sml.cost).hex()
                     == np.float64(ml.cost).hex()
                     and bool((sml.assign == ml.assign).all()))
        cell.update({
            "streamed_chunks_checked": chunks,
            "streamed_levels_bit_identical": levels_ok,
            "streamed_vcycle_bit_identical": vcycle_ok,
        })

    if run_flat:
        flat = out["flat"]
        cell.update({
            "flat_wall_s": round(best["flat"], 4),
            "flat_cost": flat.cost,
            "flat_iterations": flat.iterations,
            "speedup_vs_flat": round(best["flat"] / best["multilevel"], 2),
            "cost_ratio_vs_flat": ml.cost / flat.cost,
        })
    else:
        cell["flat"] = "skipped (V-cycle-only scale cell: the flat " \
                       "engine's full-n sweeps exceed the cell budget)"
    return cell


def run_admission_cell(n: int, m: int, seed: int = 0, reps: int = 2):
    """AssemblyCache pair-frequency admission regression (the scan-thrash
    fix): a uniform round-robin scan over more pair assemblies than the
    byte budget holds used to evict on every miss (zero steady-state
    hits).  The second-touch admission gate freezes a resident set
    instead: after warmup, evictions must stay FLAT while hits keep
    accruing, and rejected assemblies must never change results — the
    starved-budget full solve is compared against a cache-free one."""
    from repro.core.engine import PairCutEngine, round_robin_rounds

    target_links = int(n * 4.2)
    g = synthetic_siot(n=n, target_links=target_links, seed=seed)
    net = build_edge_network(g, m, seed=seed)
    cm = CostModel(net, g, workload_for("gcn", 52))
    rng = np.random.default_rng(seed)
    init = rng.integers(0, m, size=n).astype(np.int64)
    connected = {(int(i), int(j)) for i, j in net.pairs}
    pairs = [p for rnd in round_robin_rounds(m) for p in rnd
             if p in connected]

    # Budget sized to a few resident assemblies — far fewer than the scan
    # touches, the regime the admission gate exists for.
    probe = PairCutEngine(cm, init.copy(), cache=True)
    for p in pairs:
        probe.solve_pair(*p)
    budget = max(e.nbytes for e in probe._cache.values()) * 3

    eng = PairCutEngine(cm, init.copy(), cache=True, cache_bytes=budget)
    # Three warmup scans: assemblies go resident on the first, warm
    # residuals prime on the second, and the peel-composed warm start
    # primes PEEL-KEYED residuals on converged-but-gated entries one
    # probe later still — the byte footprint (and therefore the frozen
    # resident set) only reaches steady state on the third.
    for _ in range(3):                                   # warmup scans
        for p in pairs:
            eng.solve_pair(*p)
    warm = dict(eng.cache_stats())
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        for p in pairs:
            eng.solve_pair(*p)
        best = min(best, time.perf_counter() - t0)
    steady = eng.cache_stats()

    # Trajectory invariance: admission decides WHICH assemblies are
    # retained, never their content.
    res = glad_s(cm, seed=seed, sweep="batched", cache=True,
                 cache_bytes=budget)
    ref = glad_s(cm, seed=seed, sweep="batched", cache=False)
    return {
        "n": n, "m": m, "scan_pairs": len(pairs),
        "cache_budget_assemblies": 3,
        "scan_pass_ms": round(best * 1000, 2),
        "steady_evictions": steady["evictions"] - warm["evictions"],
        "steady_hits": (steady["hits"] + steady["patched"]
                        - warm["hits"] - warm["patched"]),
        "steady_rejected": steady["rejected"] - warm["rejected"],
        "admission_cost": res.cost,
        "admission_rel_cost_err": abs(res.cost - ref.cost)
        / max(abs(ref.cost), 1e-12),
    }


def run_session_cell(n: int, m: int = 8, slots: int = 8, seed: int = 0,
                     reps: int = 2, theta_per_n: float = 0.18):
    """Cross-slot persistent LayoutSession vs per-slot rebuild over the
    GLAD-A adaptive loop (the examples/adaptive_relayout.py workload):
    the graph evolves every slot and the scheduler picks GLAD-E or
    GLAD-S.  Both arms replay the IDENTICAL precomputed slot stream,
    interleaved in the same noise window; only the per-slot ``step()``
    calls are timed — the untimed ``__init__`` full solve is what warms
    the session arm's engine, exactly the deployment shape (the engine
    already exists when slot 1 arrives).  Exact-parity gates: per-slot
    costs, algorithm choices and the final assignment must be identical
    across arms — the session may only change wall time."""
    from repro.core.evolution import apply_delta, evolution_trace
    from repro.core.glad_a import GladA
    from repro.graphs.datagraph import synthetic_yelp

    g0 = synthetic_yelp(n=n, target_links=int(n * 1.25), seed=seed)
    net = build_edge_network(g0, m, seed=seed)
    gnn = workload_for("gat", 100)
    # Drift SLA scaled per-vertex so the stream exercises BOTH branches:
    # GLAD-E carries most slots, GLAD-S fires on the occasional breach.
    th = theta_per_n * n
    graphs, cur = [], g0
    for delta in evolution_trace(g0, slots, pct_links=0.02,
                                 pct_vertices=0.01, seed=1):
        cur = apply_delta(cur, delta)
        graphs.append(cur)

    def run_arm(session: bool):
        sched = GladA(net, gnn, g0, theta=th, R=3, seed=seed,
                      session=session)
        t_steps = 0.0
        for gph in graphs:
            t0 = time.perf_counter()
            sched.step(gph)
            t_steps += time.perf_counter() - t0
        return sched, t_steps

    fns = {"session": lambda: run_arm(True),
           "rebuild": lambda: run_arm(False)}
    best = {k: float("inf") for k in fns}
    out = {}
    for _ in range(max(1, reps)):
        for key, fn in fns.items():
            out[key], t = fn()
            best[key] = min(best[key], t)
    ses, reb = out["session"], out["rebuild"]

    ses_costs = [r.cost for r in ses.records]
    reb_costs = [r.cost for r in reb.records]
    trajectory_match = (
        ses_costs == reb_costs
        and [r.algorithm for r in ses.records]
        == [r.algorithm for r in reb.records]
        and bool((ses.assign == reb.assign).all()))
    rel_err = abs(ses.last_cost - reb.last_cost) / max(
        abs(reb.last_cost), 1e-12)
    return {
        "scenario": "glad_a",
        "n": n, "m": m, "slots": slots, "theta": round(th, 2),
        "glad_s_slots": sum(1 for r in ses.records[1:]
                            if r.algorithm == "glad-s"),
        "session_relayout_s": round(best["session"], 4),
        "rebuild_relayout_s": round(best["rebuild"], 4),
        "session_per_relayout_ms": round(best["session"] / slots * 1e3, 2),
        "rebuild_per_relayout_ms": round(best["rebuild"] / slots * 1e3, 2),
        "session_speedup": round(best["rebuild"] / best["session"], 2),
        "session_final_cost": ses.last_cost,
        "rebuild_final_cost": reb.last_cost,
        "session_rel_cost_err": rel_err,
        "trajectory_match": trajectory_match,
        "session_adoptions": ses.session.adoptions,
        "session_rebinds": ses.session.rebinds,
    }


def run_session_fault_cell(n: int, m: int = 8, seed: int = 0,
                           reps: int = 2, cycles: int = 3):
    """Cross-slot persistent LayoutSession vs per-event rebuild over the
    ElasticCoordinator fault loop — the session's headline regime.  A
    flapping-straggler event stream (one hard degrade that really
    migrates work, three mild flaps the relayout CONFIRMS at zero
    moves, every server revived after) relayouts on a graph that never
    changes, so the adopted engine's assemblies survive as column
    patches (degrade/revive reprices whole unary columns but leaves tau
    — and therefore every internal arc — intact) and retained residuals
    warm-repair instead of re-pushing flow.  Both arms replay the
    IDENTICAL event stream, interleaved in the same noise window; only
    the on_straggler/on_revive calls are timed.  Exact-parity gates:
    per-event relayout costs, per-event migration counts and the final
    assignment must be identical across arms."""
    from repro.core.partition import data_partition
    from repro.graphs.datagraph import synthetic_yelp
    from repro.runtime.fault import ElasticCoordinator

    g = synthetic_yelp(n=n, target_links=int(n * 1.25), seed=seed)
    net = build_edge_network(g, m, seed=seed)
    gnn = workload_for("gat", 100)
    part = data_partition(g, gnn, num_parts=m, net=net, seed=seed)
    events = []
    for _ in range(cycles):
        for s, f in ((1, 2.0), (5, 1.5), (2, 1.5), (6, 1.5)):
            events += [("deg", s, f), ("rev", s)]

    def run_arm(session: bool):
        coord = ElasticCoordinator(net, g, gnn, part, session=session)
        t_events = 0.0
        for ev in events:
            t0 = time.perf_counter()
            if ev[0] == "deg":
                coord.on_straggler([ev[1]], ev[2])
            else:
                coord.on_revive([ev[1]])
            t_events += time.perf_counter() - t0
        return coord, t_events

    fns = {"session": lambda: run_arm(True),
           "rebuild": lambda: run_arm(False)}
    best = {k: float("inf") for k in fns}
    out = {}
    for _ in range(max(1, reps)):
        for key, fn in fns.items():
            out[key], t = fn()
            best[key] = min(best[key], t)
    ses, reb = out["session"], out["rebuild"]

    ses_costs = [e.new_cost for e in ses.events]
    reb_costs = [e.new_cost for e in reb.events]
    ses_moved = [len(e.moved) for e in ses.events]
    reb_moved = [len(e.moved) for e in reb.events]
    trajectory_match = (
        ses_costs == reb_costs and ses_moved == reb_moved
        and bool((ses.part.assign == reb.part.assign).all()))
    rel_err = abs(ses_costs[-1] - reb_costs[-1]) / max(
        abs(reb_costs[-1]), 1e-12)
    ne = len(events)
    return {
        "scenario": "fault_loop",
        "n": n, "m": m, "events": ne, "cycles": cycles,
        "migrated_total": int(sum(ses_moved)),
        "confirm_events": int(sum(1 for c in ses_moved if c == 0)),
        "session_relayout_s": round(best["session"], 4),
        "rebuild_relayout_s": round(best["rebuild"], 4),
        "session_per_relayout_ms": round(best["session"] / ne * 1e3, 2),
        "rebuild_per_relayout_ms": round(best["rebuild"] / ne * 1e3, 2),
        "session_speedup": round(best["rebuild"] / best["session"], 2),
        "session_final_cost": ses_costs[-1],
        "rebuild_final_cost": reb_costs[-1],
        "session_rel_cost_err": rel_err,
        "trajectory_match": trajectory_match,
        "session_adoptions": ses._session.adoptions,
        "session_rebinds": ses._session.rebinds,
    }


def _rss_probe(spec_json: str) -> int:
    """Hidden ``--rss-probe`` arm: ONE coarsening build in a fresh process.

    ``ru_maxrss`` is a process-lifetime high-water mark, so a streamed vs
    in-core peak-RSS A/B inside one process would only ever measure the
    larger arm — each arm runs in its own subprocess and the parent
    interleaves the launches in the same noise window.  ``peak_rss_kb``
    is read IMMEDIATELY after the coarsening build, so the probe solve
    cannot mask the arms' difference; the feature matrix (coarsening
    never reads it) and the network's pre-copy mu (``CostModel`` owns a
    defensive copy) are dropped up front for the same reason — inert
    ballast common to both arms only dilutes the measured ratio.  Prints
    a single JSON line: peak RSS, coarsening wall time, and the parity
    evidence (level sizes, per-rung cluster checksums, and the final
    cost of a deterministic coarsest-level probe solve) the parent
    compares bitwise across arms."""
    import dataclasses
    import resource

    from repro.core.multilevel import COARSEN_TO, build_levels

    spec = json.loads(spec_json)
    n, m, seed = spec["n"], spec["m"], spec.get("seed", 0)
    coarsen_to = spec.get("coarsen_to") or COARSEN_TO
    g = synthetic_siot(n=n, target_links=int(n * 4.2), seed=seed)
    g = dataclasses.replace(g, features=None, labels=None)
    net = build_edge_network(g, m, seed=seed,
                             mu_factor=spec.get("mu_factor", 2.0))
    cm = CostModel(net, g, workload_for("gcn", 52))
    del net
    base_rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    t0 = time.perf_counter()
    stack = build_levels(cm, coarsen_to=coarsen_to,
                         chunk_vertices=spec.get("chunk_vertices"))
    wall = time.perf_counter() - t0
    peak_rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    coarsest = stack[-1].cm
    probe = glad_s(coarsest, R=coarsest.net.m, seed=0, sweep="batched")
    print(json.dumps({
        "peak_rss_kb": peak_rss,
        "base_rss_kb": base_rss,
        "coarsen_wall_s": round(wall, 4),
        "level_sizes": [lvl.cm.graph.n for lvl in stack],
        "cluster_checksums": _level_checksums(stack),
        "coarsest_probe_cost": probe.cost,
        "coarsest_probe_cost_hex": np.float64(probe.cost).hex(),
    }))
    return 0


def run_streamed_memory_cell(n: int, m: int = 32, seed: int = 0,
                             reps: int = 2, coarsen_to=None,
                             chunk_vertices="auto"):
    """Streamed vs in-core coarsening: peak RSS, one subprocess per arm.

    The tentpole's memory claim measured honestly: ``build_levels`` walks
    every level in core (full-CSR gate/matching/contraction arrays), the
    streamed path walks bounded vertex windows — same hierarchy
    bit-for-bit, different transient footprint.  Each probe builds the
    instance, coarsens once, then runs a deterministic coarsest-level
    probe solve; the arms must agree EXACTLY on level sizes, every
    cluster checksum and the probe cost hex (``streamed_bit_identical``
    feeds --fail-on-mismatch, ``coarsest_probe_cost`` feeds
    --check-parity).  Peak RSS per arm is the min over interleaved
    repetitions; the n=500k cell's ratio gate (streamed <= 60% of
    in-core) is checked by ``_verify_cost_parity``."""
    import os
    import pathlib
    import subprocess

    here = pathlib.Path(__file__).resolve()
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(here.parent.parent / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))

    def probe(chunk):
        spec = json.dumps({"n": n, "m": m, "seed": seed, "mu_factor": 2.0,
                           "coarsen_to": coarsen_to,
                           "chunk_vertices": chunk})
        cp = subprocess.run([sys.executable, str(here), "--rss-probe",
                             spec], capture_output=True, text=True,
                            env=env, check=True)
        return json.loads(cp.stdout.strip().splitlines()[-1])

    arms = {"incore": None, "streamed": chunk_vertices}
    best = {k: None for k in arms}
    for _ in range(max(1, reps)):
        for key, chunk in arms.items():
            got = probe(chunk)
            if (best[key] is None
                    or got["peak_rss_kb"] < best[key]["peak_rss_kb"]):
                best[key] = got
    inc, st = best["incore"], best["streamed"]
    parity = (inc["level_sizes"] == st["level_sizes"]
              and inc["cluster_checksums"] == st["cluster_checksums"]
              and inc["coarsest_probe_cost_hex"]
              == st["coarsest_probe_cost_hex"])
    return {
        "scenario": "coarsen_memory",
        "n": n, "m": m, "chunk_vertices": chunk_vertices,
        "levels": len(inc["level_sizes"]),
        "incore_peak_rss_gb": round(inc["peak_rss_kb"] / 1e6, 3),
        "streamed_peak_rss_gb": round(st["peak_rss_kb"] / 1e6, 3),
        "streamed_rss_ratio": round(st["peak_rss_kb"]
                                    / inc["peak_rss_kb"], 3),
        "incore_coarsen_wall_s": inc["coarsen_wall_s"],
        "streamed_coarsen_wall_s": st["coarsen_wall_s"],
        "streamed_bit_identical": parity,
        "coarsest_probe_cost": inc["coarsest_probe_cost"],
    }


def run_stack_reuse_cell(n: int, m: int = 16, seed: int = 0,
                         rounds: int = 3, reps: int = 2,
                         mu_factor: float = 2.0, coarsen_to=None,
                         churn: float = 0.7):
    """Persistent LevelStack vs fresh coarsening over repeated large-churn
    relayouts — the GLAD-E escalation regime the stack exists for.

    Each round scrambles >50% of the assignment (random server flips:
    effective churn ~= churn * (m-1)/m) and re-escalates to the V-cycle;
    the session arm serves coarsening off the LayoutSession's LevelStack
    (the graph never changes, so every level refreshes with zero
    rebuilds), the fresh arm pays ``build_levels`` from scratch every
    time.  Both arms must agree EXACTLY per round — cost hex, history
    hex, assignment, moved set (the stack may only change wall time,
    never bits).  The headline number is the per-escalation coarsening
    A/B: a refresh ``acquire`` off the populated stack vs a fresh
    ``build_levels``, interleaved best-of-reps; the >= 1.3x gate is
    checked by ``_verify_cost_parity``."""
    from repro.core.engine import LayoutSession
    from repro.core.multilevel import COARSEN_TO, build_levels

    if coarsen_to is None:
        coarsen_to = COARSEN_TO
    g = synthetic_siot(n=n, target_links=int(n * 4.2), seed=seed)
    net = build_edge_network(g, m, seed=seed, mu_factor=mu_factor)
    cm = CostModel(net, g, workload_for("gcn", 52))

    def run_arm(use_session):
        ses = LayoutSession() if use_session else None
        rng = np.random.default_rng(seed + 1)
        res = glad_s(cm, seed=seed, sweep="batched", multilevel=True,
                     coarsen_to=coarsen_to, session=ses)
        outs, churns, t_esc = [res], [], 0.0
        for r in range(rounds):
            init = res.assign.copy()
            flip = rng.random(n) < churn
            init[flip] = rng.integers(0, m, size=int(flip.sum()))
            churns.append(float(np.mean(init != res.assign)))
            t0 = time.perf_counter()
            res = glad_s(cm, init=init, seed=seed + 1 + r, sweep="batched",
                         multilevel=True, coarsen_to=coarsen_to,
                         session=ses)
            t_esc += time.perf_counter() - t0
            outs.append(res)
        return ses, outs, churns, t_esc

    best = {"session": float("inf"), "fresh": float("inf")}
    out = {}
    for _ in range(max(1, reps)):
        for key, use in (("session", True), ("fresh", False)):
            ses, outs, churns, t = run_arm(use)
            out[key] = (ses, outs, churns)
            best[key] = min(best[key], t)
    ses, s_outs, churns = out["session"]
    _, f_outs, _ = out["fresh"]

    def sig(res):
        return (np.float64(res.cost).hex(),
                tuple(np.float64(h).hex() for h in res.history),
                res.assign.tobytes(),
                None if res.moved is None
                else np.sort(res.moved).tobytes())

    trajectory_match = all(sig(a) == sig(b)
                           for a, b in zip(s_outs, f_outs))
    lstack = ses.level_stack(coarsen_to=coarsen_to)
    builds, refreshes = lstack.builds, lstack.refreshes
    last = s_outs[-1].coarsen or {}

    # Per-escalation coarsening A/B (counters above captured first: the
    # timing acquires below are extra refreshes on the same stack).
    t_refresh = t_fresh = float("inf")
    for _ in range(max(2, reps)):
        t0 = time.perf_counter()
        lstack.acquire(cm)
        t_refresh = min(t_refresh, time.perf_counter() - t0)
        t0 = time.perf_counter()
        build_levels(cm, coarsen_to=coarsen_to)
        t_fresh = min(t_fresh, time.perf_counter() - t0)

    s_cost, f_cost = s_outs[-1].cost, f_outs[-1].cost
    return {
        "n": n, "m": m, "coarsen_to": coarsen_to, "rounds": rounds,
        "churn_frac": churn,
        "measured_churn": round(float(np.mean(churns)), 3),
        "stack_builds": builds,
        "stack_refreshes": refreshes,
        "stack_levels_reused": last.get("reused"),
        "stack_levels_rebuilt": last.get("rebuilt"),
        "refresh_acquire_ms": round(t_refresh * 1e3, 2),
        "fresh_build_ms": round(t_fresh * 1e3, 2),
        "stack_coarsen_speedup": round(t_fresh / t_refresh, 2),
        "session_escalation_s": round(best["session"], 4),
        "fresh_escalation_s": round(best["fresh"], 4),
        "session_relayout_speedup": round(best["fresh"]
                                          / best["session"], 2),
        "trajectory_match": trajectory_match,
        "stack_final_cost": s_cost,
        "fresh_final_cost": f_cost,
        "stack_rel_cost_err": abs(s_cost - f_cost)
        / max(abs(f_cost), 1e-12),
    }


def _verify_cost_parity(out: dict, tol: float = 1e-9):
    """Every cell's engine paths must agree on the final cost.  Returns a
    list of human-readable violations (empty = pass)."""
    bad = []
    for cell in out.get("cells", []):
        for key in ("rel_cost_err_incremental", "rel_cost_err_batched"):
            if cell.get(key, 0.0) > tol:
                bad.append(f"cells n={cell['n']} m={cell['m']}: "
                           f"{key}={cell[key]:.3e} > {tol:g}")
    for cell in out.get("round_solver_cells", []):
        for key in ("first_pass_rel_cost_err",
                    "rel_cost_err_block_vs_pairwise"):
            if cell.get(key, 0.0) > tol:
                bad.append(f"round n={cell['n']} m={cell['m']}: "
                           f"{key}={cell[key]:.3e} > {tol:g}")
    for cell in out.get("convergence_cells", []):
        for key in ("cached_rel_cost_err", "warm_rel_cost_err",
                    "rel_cost_err_vs_pr2"):
            if (cell.get(key) or 0.0) > tol:
                bad.append(f"conv n={cell['n']} m={cell['m']}: "
                           f"{key}={cell[key]:.3e} > {tol:g}")
    for cell in out.get("resolve_cells", []):
        for key in ("rel_cost_err_cached_vs_cold", "rel_cost_err_warm_vs_cold",
                    "rel_cost_err_vs_pr3"):
            if (cell.get(key) or 0.0) > tol:
                bad.append(f"resolve n={cell['n']} m={cell['m']}: "
                           f"{key}={cell[key]:.3e} > {tol:g}")
    for cell in out.get("multilevel_cells", []):
        where = f"multilevel n={cell['n']} m={cell['m']}"
        ratio = cell.get("cost_ratio_vs_flat")
        if ratio is not None and ratio > 1.05:
            bad.append(f"{where}: cost_ratio_vs_flat={ratio:.4f} > 1.05")
        if not cell.get("coarsening_deterministic", True):
            bad.append(f"{where}: coarsening checksums diverged on rebuild")
        if not cell.get("finest_replay_bit_identical", True):
            bad.append(f"{where}: finest refinement != flat-engine replay")
        if not cell.get("streamed_levels_bit_identical", True):
            bad.append(f"{where}: streamed coarsening hierarchy diverged "
                       "from in-core build_levels")
        if not cell.get("streamed_vcycle_bit_identical", True):
            bad.append(f"{where}: streamed V-cycle cost/assignment "
                       "diverged from the in-core V-cycle")
    for cell in out.get("streamed_memory_cells", []):
        where = f"streamed-memory n={cell['n']} m={cell['m']}"
        if not cell.get("streamed_bit_identical", True):
            bad.append(f"{where}: streamed arm's hierarchy/probe-cost "
                       "diverged from the in-core arm")
        if (cell["n"] >= 500_000
                and cell.get("streamed_rss_ratio", 0.0) > 0.60):
            bad.append(f"{where}: streamed_rss_ratio="
                       f"{cell['streamed_rss_ratio']:.3f} > 0.60")
    for cell in out.get("stack_reuse_cells", []):
        where = f"stack-reuse n={cell['n']} m={cell['m']}"
        if not cell.get("trajectory_match", True):
            bad.append(f"{where}: session arm's relayout trajectory "
                       "diverged from the fresh-build arm")
        if cell.get("stack_rel_cost_err", 0.0) > tol:
            bad.append(f"{where}: stack_rel_cost_err="
                       f"{cell['stack_rel_cost_err']:.3e} > {tol:g}")
        if cell.get("stack_refreshes", 1) <= 0:
            bad.append(f"{where}: the LevelStack never refreshed "
                       "(every escalation rebuilt from scratch)")
        if cell.get("stack_coarsen_speedup", 99.0) < 1.3:
            bad.append(f"{where}: stack_coarsen_speedup="
                       f"{cell['stack_coarsen_speedup']} < 1.3")
    for cell in out.get("admission_cells", []):
        where = f"admission n={cell['n']} m={cell['m']}"
        if cell.get("admission_rel_cost_err", 0.0) > tol:
            bad.append(f"{where}: admission_rel_cost_err="
                       f"{cell['admission_rel_cost_err']:.3e} > {tol:g}")
        if cell.get("steady_evictions", 0) != 0:
            bad.append(f"{where}: steady_evictions="
                       f"{cell['steady_evictions']} (scan still thrashes)")
        if cell.get("steady_rejected", 1) <= 0:
            bad.append(f"{where}: admission gate never engaged "
                       "(no budget pressure — cell mis-sized)")
        if cell.get("steady_hits", 1) <= 0:
            bad.append(f"{where}: resident set served no hits")
    for cell in out.get("session_cells", []):
        where = (f"session[{cell.get('scenario', '?')}] "
                 f"n={cell['n']} m={cell['m']}")
        if cell.get("session_rel_cost_err", 0.0) > tol:
            bad.append(f"{where}: session_rel_cost_err="
                       f"{cell['session_rel_cost_err']:.3e} > {tol:g}")
        if not cell.get("trajectory_match", True):
            bad.append(f"{where}: session arm's per-slot trajectory "
                       "diverged from the per-slot-rebuild arm")
        if cell.get("session_rebinds", 1) <= 0:
            bad.append(f"{where}: session never rebound an engine "
                       "(adopt silently rebuilt every slot)")
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: n=1k/5k engine cells, 5k round cells")
    ap.add_argument("--reps", type=int, default=3,
                    help="repetitions per path; min wall time is reported")
    ap.add_argument("--skip-seed-cells", action="store_true",
                    help="only the round-solver section (fast iteration)")
    ap.add_argument("--fail-on-mismatch", action="store_true",
                    help="exit nonzero if any cell's engine paths disagree "
                         "on the final cost (the CI smoke gate)")
    ap.add_argument("--pr2-tree", default=None,
                    help="path to a checkout/worktree of commit 3c2dd42: "
                         "re-measures the PR-2 reference per cell in the "
                         "same noise window instead of using the vendored "
                         "constants")
    ap.add_argument("--pr3-tree", default=None,
                    help="path to a checkout/worktree of commit d9dfb92: "
                         "re-measures the PR-3 reference for the "
                         "converged-regime resolve cells in the same noise "
                         "window")
    ap.add_argument("--scale-cells", action="store_true",
                    help="add the n=2M streamed first-pass V-cycle cell "
                         "(the weekly slow-tier scale gate; ~half an "
                         "hour on the reference box)")
    ap.add_argument("--rss-probe", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--out", default="BENCH_layout.json")
    args = ap.parse_args(argv)

    if args.rss_probe is not None:
        return _rss_probe(args.rss_probe)

    cells = []
    if not args.skip_seed_cells:
        sizes = [1000, 5000] if args.quick else [1000, 5000, 20000]
        for n in sizes:
            for m in (8, 16):
                cell = run_cell(n, m, reps=args.reps)
                cells.append(cell)
                print(f"n={n:>6} m={m:>2}: seed {cell['seed_wall_s']:.2f}s "
                      f"incremental {cell['incremental_wall_s']:.2f}s "
                      f"({cell['incremental_speedup']}x) "
                      f"batched {cell['batched_wall_s']:.2f}s "
                      f"({cell['batched_speedup']}x) -> speedup "
                      f"{cell['speedup']}x rel_err {cell['rel_cost_err']:.2e}")

    round_grid = ([(5000, 16), (5000, 32)] if args.quick else
                  [(5000, 16), (5000, 32), (20000, 16), (20000, 32),
                   (50000, 16), (50000, 32)])
    round_cells = []
    for n, m in round_grid:
        full = n <= 20000
        if not full:
            print(f"n={n:>6} m={m:>2}: skipping full-convergence runs "
                  f"(per-round first-pass measurement only)")
        cell = run_round_cell(n, m, reps=args.reps, full_runs=full,
                              ref_tree=args.pr2_tree)
        round_cells.append(cell)
        print(f"n={n:>6} m={m:>2}: per-round pairwise "
              f"{cell['pairwise_per_round_ms']}ms block "
              f"{cell['block_per_round_ms']}ms auto "
              f"{cell['auto_per_round_ms']}ms cached "
              f"{cell['cached_per_round_ms']}ms pr2 "
              f"{cell['pr2_per_round_ms']}ms -> auto vs pr2 "
              f"{cell['round_speedup_vs_pr2']}x, vs pairwise "
              f"{cell['round_speedup_vs_pairwise']}x")

    # Converged-regime re-solve cells: the warm start's target regime.
    # One small cell runs even in quick/smoke mode (the CI warm-start
    # smoke: its exact-parity keys feed the --fail-on-mismatch gate and
    # the committed resolve_final_cost feeds --check-parity).
    resolve_grid = ([(5000, 16)] if args.quick else
                    [(5000, 16), (20000, 16), (50000, 32)])
    resolve_cells = []
    for n, m in resolve_grid:
        cell = run_resolve_cell(n, m, reps=min(args.reps, 2),
                                ref_tree=args.pr3_tree)
        resolve_cells.append(cell)
        print(f"n={n:>6} m={m:>2}: converged reprobe cold "
              f"{cell['reprobe_cold_ms']}ms cached "
              f"{cell['reprobe_cached_ms']}ms warm "
              f"{cell['reprobe_warm_ms']}ms "
              f"({cell['warm_reprobe_speedup_vs_cold']}x vs cold); "
              f"perturb cold {cell['perturb_cold_ms']}ms cached "
              f"{cell['perturb_cached_ms']}ms warm "
              f"{cell['perturb_warm_ms']}ms")

    # Multilevel V-cycle vs flat, interleaved (PR-6; streamed knobs
    # PR-10).  The quick cell feeds --fail-on-mismatch (quality/
    # determinism/bit-identity gates, now including streamed-vs-in-core
    # parity) and --check-parity (pinned costs); the full grid adds the
    # 50k headline cell and the 500k V-cycle-only scale cell, which now
    # runs STREAMED with slimmed telemetry (bit-identical cost by the
    # streaming contract, bounded-window coarsening RSS).  --scale-cells
    # adds the n=2M streamed first-pass cell (weekly slow tier).
    ml_grid = ([dict(n=5000, m=16, coarsen_to=256)] if args.quick else
               [dict(n=5000, m=16, coarsen_to=256),
                dict(n=50000, m=32),
                dict(n=500000, m=32, run_flat=False,
                     chunk_vertices="auto", record_levels=False)])
    if args.scale_cells:
        ml_grid.append(dict(n=2_000_000, m=32, run_flat=False,
                            chunk_vertices="auto", record_levels=False))
    ml_cells = []
    for spec in ml_grid:
        run_flat = spec.get("run_flat", True)
        # The flat-skipped scale cells are completion/memory gates, not
        # timing comparisons: one rep.
        cell = run_multilevel_cell(
            reps=min(args.reps, 2) if run_flat else 1, **spec)
        ml_cells.append(cell)
        n, m = cell["n"], cell["m"]
        if run_flat:
            print(f"n={n:>6} m={m:>2}: multilevel "
                  f"{cell['multilevel_wall_s']:.2f}s flat "
                  f"{cell['flat_wall_s']:.2f}s "
                  f"({cell['speedup_vs_flat']}x, cost ratio "
                  f"{cell['cost_ratio_vs_flat']:.4f}, "
                  f"{cell['levels']} levels, replay_ok="
                  f"{cell['finest_replay_bit_identical']}, streamed_ok="
                  f"{cell.get('streamed_vcycle_bit_identical', 'n/a')})")
        else:
            print(f"n={n:>7} m={m:>2}: multilevel "
                  f"{cell['multilevel_wall_s']:.2f}s "
                  f"({cell['levels']} levels, flat skipped, "
                  f"chunk={cell['chunk_vertices']}, "
                  f"maxrss {cell['max_rss_gb']}GB)")

    # Streamed-vs-in-core coarsening memory A/B (PR-10): one subprocess
    # per arm (ru_maxrss is process-lifetime), launches interleaved in
    # the same noise window.  The quick cell gates exact parity in
    # --smoke/--check-parity; the full grid adds the n=500k cell whose
    # RSS ratio must be <= 0.60.
    mem_grid = [(20000, 32)] if args.quick else [(20000, 32),
                                                 (500000, 32)]
    mem_cells = []
    for n, m in mem_grid:
        cell = run_streamed_memory_cell(n, m, reps=min(args.reps, 2))
        mem_cells.append(cell)
        print(f"n={n:>7} m={m:>2}: coarsen peak RSS in-core "
              f"{cell['incore_peak_rss_gb']}GB streamed "
              f"{cell['streamed_peak_rss_gb']}GB (ratio "
              f"{cell['streamed_rss_ratio']}), wall "
              f"{cell['incore_coarsen_wall_s']}s vs "
              f"{cell['streamed_coarsen_wall_s']}s, parity="
              f"{cell['streamed_bit_identical']}")

    # Persistent LevelStack vs fresh coarsening over repeated
    # large-churn relayouts (PR-10): exact trajectory parity + the
    # >= 1.3x per-escalation coarsening speedup gate.
    sr_grid = ([(5000, 16, 256)] if args.quick else
               [(5000, 16, 256), (20000, 16, None)])
    sr_cells = []
    for n, m, ct in sr_grid:
        cell = run_stack_reuse_cell(n, m, coarsen_to=ct,
                                    reps=min(args.reps, 2))
        sr_cells.append(cell)
        print(f"n={n:>6} m={m:>2}: stack refresh "
              f"{cell['refresh_acquire_ms']}ms vs fresh build "
              f"{cell['fresh_build_ms']}ms "
              f"({cell['stack_coarsen_speedup']}x per escalation, "
              f"churn {cell['measured_churn']}, "
              f"{cell['stack_refreshes']} refreshes / "
              f"{cell['stack_builds']} build, match="
              f"{cell['trajectory_match']})")

    # AssemblyCache admission regression (PR-6 satellite): scan-resistance
    # + exact-parity gates feed --fail-on-mismatch.
    adm_cells = []
    for n, m in [(5000, 16)]:
        cell = run_admission_cell(n, m, reps=min(args.reps, 2))
        adm_cells.append(cell)
        print(f"n={n:>6} m={m:>2}: admission scan pass "
              f"{cell['scan_pass_ms']}ms, steady evictions "
              f"{cell['steady_evictions']}, hits {cell['steady_hits']}, "
              f"rejected {cell['steady_rejected']}")

    # Cross-slot persistent session vs per-slot rebuild (PR-9), two
    # scenarios: the coordinator fault loop (headline — column patches +
    # warm repairs on an unchanged graph) and the GLAD-A adaptive loop
    # (recorded honestly: masked evolution slots carry ~nothing at
    # scale).  The quick cells feed --fail-on-mismatch (exact final-cost
    # parity + trajectory match + rebind engagement) and --check-parity;
    # the full grid adds the n=20k cells.
    ses_grid = [(1000, 8)] if args.quick else [(1000, 8), (20000, 8)]
    ses_cells = []
    for n, m in ses_grid:
        cell = run_session_fault_cell(n, m, reps=min(args.reps, 2))
        ses_cells.append(cell)
        print(f"n={n:>6} m={m:>2}: session fault-loop per-relayout "
              f"{cell['session_per_relayout_ms']}ms rebuild "
              f"{cell['rebuild_per_relayout_ms']}ms "
              f"({cell['session_speedup']}x over {cell['events']} events, "
              f"{cell['confirm_events']} confirms, "
              f"rebinds {cell['session_rebinds']}, "
              f"match={cell['trajectory_match']})")
    for n, m in ses_grid:
        cell = run_session_cell(n, m, reps=min(args.reps, 2))
        ses_cells.append(cell)
        print(f"n={n:>6} m={m:>2}: session glad-a per-relayout "
              f"{cell['session_per_relayout_ms']}ms rebuild "
              f"{cell['rebuild_per_relayout_ms']}ms "
              f"({cell['session_speedup']}x, glad-s on "
              f"{cell['glad_s_slots']}/{cell['slots']} slots, "
              f"rebinds {cell['session_rebinds']}, "
              f"match={cell['trajectory_match']})")

    conv_cells = []
    if not args.quick:
        for n, m in round_grid:
            cell = run_conv_cell(n, m, reps=min(args.reps, 2),
                                 ref_tree=args.pr2_tree)
            conv_cells.append(cell)
            print(f"n={n:>6} m={m:>2}: convergence per-round default "
                  f"{cell['default_per_round_ms']}ms cached "
                  f"{cell['cached_per_round_ms']}ms warm "
                  f"{cell['warm_per_round_ms']}ms pr2 "
                  f"{cell['pr2_per_round_ms']}ms -> vs pr2 "
                  f"{cell['conv_speedup_vs_pr2']}x "
                  f"(cost parity vs pr2: "
                  f"{cell['rel_cost_err_vs_pr2']:.1e})")

    out = {
        "benchmark": "layout_engine",
        "graph": "synthetic_siot (links ~ 4.2n)",
        "workload": "gcn d=52",
        "R": "exhaustive |D|(|D|-1)/2",
        "methodology": "interleaved best-of-reps; round cells time one "
                       "full round-robin pass from a fixed random init "
                       "with a fresh engine per rep; convergence cells "
                       "repeat passes until none accepts; resolve cells "
                       "converge once then time forced re-probe passes "
                       "and deterministic two-vertex perturb/re-converge "
                       "episodes (the warm start's converged regime); "
                       "pr2/pr3 references measured at commits "
                       "3c2dd42/d9dfb92 on THIS box with the same drivers "
                       "via worktree subprocesses in the same noise "
                       "window, pr1 at commit 5827408 on the PR-2 box",
        "reference_warning": "pr1/pr2 per-round constants are vendored "
                             "same-box measurements (PR1_PER_ROUND_MS / "
                             "PR2_PER_ROUND_MS / PR2_CONV_PER_ROUND_MS); "
                             "rerunning on different hardware makes those "
                             "ratios cross-machine — re-measure the "
                             "reference commits before citing them",
        "cells": cells,
        "round_solver_cells": round_cells,
        "resolve_cells": resolve_cells,
        "multilevel_cells": ml_cells,
        "streamed_memory_cells": mem_cells,
        "stack_reuse_cells": sr_cells,
        "admission_cells": adm_cells,
        "session_cells": ses_cells,
        "convergence_cells": conv_cells,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")

    if args.fail_on_mismatch:
        bad = _verify_cost_parity(out)
        if bad:
            print("COST PARITY FAILURES:")
            for b in bad:
                print("  " + b)
            return 1
        print("cost parity: all engine paths agree")
    return 0


def check_parity(ref_path: str = "BENCH_layout.json",
                 rtol: float = 1e-12) -> int:
    """Re-run the quick grid and compare every final cost against the
    committed ``BENCH_layout.json`` — nonzero exit on divergence, so CI
    catches silent cost regressions, not just crashes.

    The grid is deterministic (fixed seeds, exhaustive R), so on the same
    software stack the costs must match to float precision; ``rtol`` leaves
    headroom for BLAS-level reduction-order differences across machines."""
    import tempfile

    with open(ref_path) as f:
        ref = json.load(f)
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tmp:
        tmp_path = tmp.name
    main(["--quick", "--reps", "1", "--out", tmp_path])
    with open(tmp_path) as f:
        got = json.load(f)
    import os
    os.unlink(tmp_path)

    def index(doc, section, keys):
        # scenario disambiguates same-size cells (session fault/glad-a)
        return {(c.get("scenario"), c["n"], c["m"]):
                {k: c[k] for k in keys if k in c}
                for c in doc.get(section, [])}

    checks = [
        ("cells", ("seed_cost", "incremental_cost", "batched_cost")),
        ("round_solver_cells",
         ("sequential_cost", "batched_pairwise_cost", "batched_block_cost",
          "first_pass_cost")),
        ("resolve_cells", ("resolve_final_cost",)),
        ("multilevel_cells", ("flat_cost", "multilevel_cost")),
        ("streamed_memory_cells", ("coarsest_probe_cost",)),
        ("stack_reuse_cells", ("stack_final_cost", "fresh_final_cost")),
        ("admission_cells", ("admission_cost",)),
        ("session_cells", ("session_final_cost", "rebuild_final_cost")),
    ]
    bad = []
    for section, keys in checks:
        ref_idx = index(ref, section, keys)
        for cell_key, vals in index(got, section, keys).items():
            if cell_key not in ref_idx:
                continue                    # quick grid ⊂ committed grid
            for k, v in vals.items():
                r = ref_idx[cell_key].get(k)
                if r is None:
                    continue
                err = abs(v - r) / max(abs(r), 1e-12)
                if err > rtol:
                    bad.append(f"{section} n={cell_key[1]} m={cell_key[2]} "
                               f"{k}: {v!r} vs committed {r!r} "
                               f"(rel {err:.3e} > {rtol:g})")
    if bad:
        print("PARITY CHECK FAILED against", ref_path)
        for b in bad:
            print("  " + b)
        return 1
    print(f"parity check OK: quick-grid costs match {ref_path} "
          f"within {rtol:g}")
    return 0


def run(full: bool = False, smoke: bool = False) -> int:
    """benchmarks.run entry point.

    The committed full-grid BENCH_layout.json is only (re)written by a
    ``--full`` section run or a direct ``python benchmarks/layout_engine.py``
    invocation; quick/smoke passes write side files so a plain
    ``python -m benchmarks.run`` cannot clobber the recorded numbers."""
    argv = []
    if smoke or not full:
        argv.append("--quick")
    if smoke:
        argv += ["--reps", "1", "--out", "BENCH_layout.smoke.json",
                 "--fail-on-mismatch"]
    elif not full:
        argv += ["--out", "BENCH_layout.quick.json"]
    return main(argv)


if __name__ == "__main__":
    sys.exit(main())
