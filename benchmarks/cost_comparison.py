"""Fig. 8/9: total system cost of Random / Greedy / GLAD-S for
GCN / GAT / GraphSAGE over SIoT and Yelp (60 heterogeneous servers).

Paper claim: >= 94-95.8% cost reduction vs the worst baseline."""
from __future__ import annotations

from benchmarks.common import cost_model, dataset, emit, fleet


def run(full: bool = False, servers: int = 60):
    rows = []
    for ds in ("siot", "yelp"):
        g = dataset(ds, full)
        net = fleet(g, servers)
        for model in ("gcn", "gat", "sage"):
            cm = cost_model(g, net, model, ds)
            r = __import__("benchmarks.common", fromlist=["run_layouts"]) \
                .run_layouts(cm)
            reduction = 1.0 - r["glad"] / r["random"]
            rows.append([ds, model, round(r["random"], 2),
                         round(r["greedy"], 2), round(r["glad"], 2),
                         f"{reduction:.3f}", round(r["glad_wall_s"], 2)])
    return emit(rows, ["dataset", "model", "cost_random", "cost_greedy",
                       "cost_glad", "reduction_vs_random", "glad_wall_s"])


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
