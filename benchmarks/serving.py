"""Serving benchmark: request-driven inference over the live ShardPlan.

Section ``serving_cells`` — the paper's target workload (Sec. II-A: a
resident GNN service answering per-user request streams) measured
end-to-end on a yelp-shaped graph:

  * a Zipf-skewed request stream drives :class:`repro.gnn.GNNServeEngine`
    (batched k-hop ego extraction -> jitted batched forward), recording
    throughput, p50/p99 latency, ego-forward trace counts, and the
    feature-cache hit ledger against the layout's halos;
  * the SAME stream prices two GLAD layouts analytically via
    :func:`repro.gnn.serving_cost` (distributed ego execution: compute at
    each vertex's owner, one result fetch per remote row) — one layout
    computed traffic-BLIND, one traffic-aware on BOTH cost axes: the
    ego-propagated ``request_traffic`` histogram reweights the unary
    compute row, and ``link_traffic`` (egos crossing each edge) scales
    the graph's edge weights so the pairwise C_T term prices the fetch
    side too — so the cell answers the paper's placement question: does
    knowing the traffic improve the layout it serves from?  Gate:
    aware <= blind.
  * every cell replays a sample of served targets through the whole-graph
    oracle ``models.forward`` and counts exact float mismatches — the GCN
    ego forward is BIT-exact vs the oracle (see tests/test_serving.py for
    why gat/sage sit ~1 ulp off), so the gate is 0 mismatches.

Section ``replication_cells`` — the move-vs-replicate A/B
(:func:`run_replication_cell`): the same stream priced on the blind, the
move-only aware, and the aware-plus-replica-overlay layouts, on the
clustered yelp grid AND on the scatter/expander SIoT graph where moves
alone can't win.  Gates: ``replicated <= aware <= blind`` orderings (and
>= 1.5x vs the best move-only layout on scatter), oracle parity of the
replicated engine, and bit-identity of replica-patched plans vs fresh
compiles.

The parity/ordering quantities are integers or exact comparisons and
machine-independent; wall-clock numbers are reported but never gated.

Usage: PYTHONPATH=src python benchmarks/serving.py [--quick] [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import CostModel, workload_for
from repro.core.glad_s import glad_s
from repro.core.partition import partition_from_assign
from repro.gnn.distributed import (compile_plan, patch_plan, plans_equal,
                                   recompile_like)
from repro.gnn.models import GNNConfig, directed_edges, forward, init_params
from repro.gnn.serving import (GNNServeEngine, link_traffic,
                               replicate_for_stream, request_traffic,
                               serving_cost, zipf_requests)
from repro.graphs.datagraph import synthetic_siot, synthetic_yelp
from repro.graphs.edgenet import build_edge_network


def _layouts(cm_blind, cm_aware, parts: int, seed: int):
    """Same solver, same seed, same R — the only difference is whether the
    cost model saw the traffic histogram."""
    blind = glad_s(cm_blind, R=parts, seed=seed, sweep="batched")
    aware = glad_s(cm_aware, R=parts, seed=seed, sweep="batched")
    return blind.assign, aware.assign


def run_serving_cell(n: int, parts: int, requests: int, seed: int = 0,
                     zipf_s: float = 1.1, batch: int = 8,
                     served: int = 256, parity_sample: int = 24) -> dict:
    g = synthetic_yelp(n=n, target_links=int(1.2 * n), seed=seed + 1)
    # mu_factor=2.0 gives the fleet real placement structure (the default
    # drowns C_M in compute; see the layout-engine bench methodology).
    net = build_edge_network(g, parts, seed=seed, mu_factor=2.0)
    gnn = workload_for("gcn", g.features.shape[1])
    cfg = GNNConfig("gcn", (g.features.shape[1], 16, 4))
    params = init_params(jax.random.PRNGKey(seed), cfg)

    hops = cfg.num_layers
    stream = zipf_requests(g.n, requests, s=zipf_s, seed=seed)
    # Ego-propagated traffic: the weight a vertex's compute row carries
    # under distributed ego execution is the number of egos touching it;
    # the weight a link carries is the number of egos crossing it (a cut
    # hot link = one result fetch per request whose ego spans it).  The
    # aware model sees both; the blind model and the serving_cost metric
    # see the plain graph.
    traffic = request_traffic(g.n, stream, graph=g, hops=hops)
    g_aware = dataclasses.replace(
        g, edge_weights=g.weights_or_ones() * link_traffic(g, stream, hops))
    cm_blind = CostModel(net, g, gnn)
    cm_aware = CostModel(net, g_aware, gnn, traffic=traffic)
    t0 = time.perf_counter()
    a_blind, a_aware = _layouts(cm_blind, cm_aware, parts, seed)
    layout_s = time.perf_counter() - t0

    cost_blind = serving_cost(cm_blind, a_blind, stream, hops)
    cost_aware = serving_cost(cm_blind, a_aware, stream, hops)

    # Serve a prefix of the stream off the traffic-aware layout.
    plan = compile_plan(
        g, partition_from_assign(g, a_aware, parts, {}), slack=0.5)
    eng = GNNServeEngine(cfg, params, g, plan, batch=batch, net=net)
    take = min(served, requests)
    eng.serve(stream[:take])
    lat = eng.latency_percentiles()
    cache = eng.cache_stats()

    # Exact-parity replay: served outputs vs the whole-graph oracle.
    oracle = np.asarray(forward(cfg, params, jnp.asarray(g.features),
                                jnp.asarray(directed_edges(g.edges))))
    sample = np.unique(stream[:take])[:parity_sample]
    out = eng.serve(sample)
    mismatches = int((out != oracle[sample]).any(axis=1).sum())

    s = eng.stats
    return {
        "n": n, "m": parts, "requests": requests, "zipf_s": zipf_s,
        "batch": batch, "served": take, "hops": hops, "seed": seed,
        "layout_wall_s": round(layout_s, 2),
        "serving_cost_blind": round(float(cost_blind), 3),
        "serving_cost_aware": round(float(cost_aware), 3),
        "aware_saving_pct": round(
            100.0 * (1.0 - cost_aware / max(cost_blind, 1e-12)), 2),
        "aware_leq_blind": bool(cost_aware <= cost_blind),
        "throughput_rps": round(s.throughput_rps, 1),
        "latency_p50_ms": round(lat["p50"] * 1e3, 2),
        "latency_p99_ms": round(lat["p99"] * 1e3, 2),
        "ego_rows_local": int(s.local_rows),
        "ego_rows_cache_hit": int(s.cache_hit_rows),
        "ego_rows_fetched": int(s.fetched_rows),
        "fetch_cost": round(float(s.fetch_cost), 3),
        "forward_traces": int(eng.fwd.stats["traces"]),
        "cache_resident_rows": int(cache["resident"]),
        "parity_sample": int(len(sample)),
        "parity_mismatches": mismatches,
    }


def run_replication_cell(kind: str, n: int, parts: int, requests: int,
                         seed: int = 0, zipf_s: float = 1.1, batch: int = 8,
                         served: int = 192, parity_sample: int = 16) -> dict:
    """Move-vs-replicate A/B over ONE stream window (Sec. ``replication``).

    Three layouts priced by the SAME traffic-blind :func:`serving_cost`
    on the SAME stream: traffic-blind GLAD, traffic-aware GLAD (the best
    move-only answer), and the aware layout plus the stream-greedy
    replica overlay (:func:`replicate_for_stream` — replicated rows serve
    at zero fetch, each charged its one-time sync).  ``kind='yelp'`` is
    the clustered grid where moves already help; ``kind='scatter'`` is
    the BA long-tail SIoT expander where PR 5/7 recorded that moves alone
    can't win — the fan-in regime replication exists for.  Gates:
    ``replicated <= aware`` and ``replicated <= blind`` everywhere, and
    on scatter a >= 1.5x reduction vs the BEST move-only layout.  The
    replicated plan also serves a live prefix (replica-tier ledger,
    oracle parity) and is patched through a move sweep asserting the
    replica tables stay bit-identical to fresh compiles."""
    if kind == "yelp":
        g = synthetic_yelp(n=n, target_links=int(1.2 * n), seed=seed + 1)
    elif kind == "scatter":
        g = synthetic_siot(n=n, target_links=int(3 * n), seed=seed + 1)
    else:
        raise ValueError(kind)
    net = build_edge_network(g, parts, seed=seed, mu_factor=2.0)
    gnn = workload_for("gcn", g.features.shape[1])
    cfg = GNNConfig("gcn", (g.features.shape[1], 16, 4))
    params = init_params(jax.random.PRNGKey(seed), cfg)
    hops = cfg.num_layers
    stream = zipf_requests(g.n, requests, s=zipf_s, seed=seed)

    traffic = request_traffic(g.n, stream, graph=g, hops=hops)
    g_aware = dataclasses.replace(
        g, edge_weights=g.weights_or_ones() * link_traffic(g, stream, hops))
    cm_blind = CostModel(net, g, gnn)
    cm_aware = CostModel(net, g_aware, gnn, traffic=traffic)
    t0 = time.perf_counter()
    a_blind, a_aware = _layouts(cm_blind, cm_aware, parts, seed)
    repl = replicate_for_stream(cm_blind, a_aware, stream, hops)
    layout_s = time.perf_counter() - t0

    cost_blind = serving_cost(cm_blind, a_blind, stream, hops)
    cost_aware = serving_cost(cm_blind, a_aware, stream, hops)
    cost_repl = serving_cost(cm_blind, a_aware, stream, hops,
                             replication=repl)
    best_move = min(cost_blind, cost_aware)
    ratio = best_move / max(cost_repl, 1e-12)

    # Same-window interleaved A/B: the move-only and replicated engines
    # drain the SAME request prefix tick-for-tick.
    part_aware = partition_from_assign(g, a_aware, parts, {})
    plan_move = compile_plan(g, part_aware, slack=0.5)
    plan_repl = compile_plan(g, part_aware, slack=0.5, replication=repl)
    eng_move = GNNServeEngine(cfg, params, g, plan_move, batch=batch,
                              net=net)
    eng_repl = GNNServeEngine(cfg, params, g, plan_repl, batch=batch,
                              net=net)
    take = min(served, requests)
    eng_move.submit(stream[:take])
    eng_repl.submit(stream[:take])
    while eng_move.queue or eng_repl.queue:
        eng_move.tick()
        eng_repl.tick()

    # Oracle parity on the replicated engine: replicas change where rows
    # are READ from, never the values — served outputs stay exact.
    oracle = np.asarray(forward(cfg, params, jnp.asarray(g.features),
                                jnp.asarray(directed_edges(g.edges))))
    sample = np.unique(stream[:take])[:parity_sample]
    out = eng_repl.serve(sample)
    mismatches = int((out != oracle[sample]).any(axis=1).sum())

    # Replica patch-stability through a live move sweep.
    rng = np.random.default_rng(seed + 7)
    cur = a_aware.copy()
    patch_ok = True
    for _ in range(3):
        movers = rng.choice(g.n, size=max(g.n // 100, 4), replace=False)
        cur = cur.copy()
        cur[movers] = rng.integers(0, parts, size=len(movers))
        patch_plan(plan_repl, g, cur)
        if plans_equal(plan_repl, recompile_like(plan_repl, g, cur)):
            patch_ok = False
    sm, sr = eng_move.stats, eng_repl.stats
    return {
        "kind": kind, "n": n, "m": parts, "requests": requests,
        "zipf_s": zipf_s, "batch": batch, "served": take, "hops": hops,
        "seed": seed, "layout_wall_s": round(layout_s, 2),
        "serving_cost_blind": round(float(cost_blind), 3),
        "serving_cost_aware": round(float(cost_aware), 3),
        "serving_cost_replicated": round(float(cost_repl), 3),
        "replicas": int(repl.count),
        "replication_gain": round(float(repl.gain), 3),
        "repl_leq_aware": bool(cost_repl <= cost_aware + 1e-9),
        "repl_leq_blind": bool(cost_repl <= cost_blind + 1e-9),
        "ratio_vs_best_move": round(float(ratio), 3),
        "throughput_rps_move": round(sm.throughput_rps, 1),
        "throughput_rps_repl": round(sr.throughput_rps, 1),
        "ego_rows_local": int(sr.local_rows),
        "ego_rows_replica_hit": int(sr.replica_hit_rows),
        "ego_rows_cache_hit": int(sr.cache_hit_rows),
        "ego_rows_fetched": int(sr.fetched_rows),
        "move_rows_fetched": int(sm.fetched_rows + sm.cache_hit_rows),
        "parity_sample": int(len(sample)),
        "parity_mismatches": mismatches,
        "patch_bit_identical": bool(patch_ok),
    }


def _merge(out_path: str, cells: list, key: str = "serving_cells") -> None:
    doc = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    doc[key] = cells
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"merged {key} into {out_path}")


def _verify(cells: list) -> list:
    bad = []
    for c in cells:
        tag = f"n={c['n']} m={c['m']}"
        if c.get("parity_mismatches", 1) != 0:
            bad.append(f"{tag}: {c['parity_mismatches']} served outputs "
                       f"diverged from the whole-graph oracle")
        if not c.get("aware_leq_blind", False):
            bad.append(f"{tag}: traffic-aware layout served WORSE than "
                       f"blind ({c['serving_cost_aware']} > "
                       f"{c['serving_cost_blind']})")
        if c.get("throughput_rps", 0) <= 0:
            bad.append(f"{tag}: zero serving throughput")
    return bad


def _verify_replication(cells: list) -> list:
    bad = []
    for c in cells:
        tag = f"{c['kind']} n={c['n']} m={c['m']}"
        if c.get("parity_mismatches", 1) != 0:
            bad.append(f"{tag}: {c['parity_mismatches']} replicated served "
                       f"outputs diverged from the whole-graph oracle")
        if not c.get("repl_leq_aware", False):
            bad.append(f"{tag}: replicated layout served WORSE than "
                       f"move-only aware ({c['serving_cost_replicated']} > "
                       f"{c['serving_cost_aware']})")
        if not c.get("repl_leq_blind", False):
            bad.append(f"{tag}: replicated layout served WORSE than blind "
                       f"({c['serving_cost_replicated']} > "
                       f"{c['serving_cost_blind']})")
        if not c.get("patch_bit_identical", False):
            bad.append(f"{tag}: patched replica plan diverged from the "
                       f"fresh compile")
        if c["kind"] == "scatter" and c.get("ratio_vs_best_move", 0) < 1.5:
            bad.append(f"{tag}: replication won only "
                       f"{c.get('ratio_vs_best_move')}x vs the best "
                       f"move-only layout (gate: >= 1.5x on scatter)")
        if (c.get("throughput_rps_move", 0) <= 0
                or c.get("throughput_rps_repl", 0) <= 0):
            bad.append(f"{tag}: zero serving throughput")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small cell only (n=800)")
    ap.add_argument("--out", default="BENCH_layout.json")
    ap.add_argument("--fail-on-mismatch", action="store_true",
                    help="exit nonzero on oracle-parity mismatches or a "
                         "traffic-aware layout that serves worse than "
                         "blind (the CI smoke gate)")
    args = ap.parse_args(argv)

    grid = [(800, 6, 4000)]
    repl_grid = [("yelp", 800, 6, 4000), ("scatter", 800, 8, 4000)]
    if not args.quick:
        grid += [(2000, 8, 10000), (3912, 8, 20000)]
        repl_grid += [("yelp", 2000, 8, 10000), ("scatter", 2000, 8, 8000)]
    cells = []
    for n, m, reqs in grid:
        cell = run_serving_cell(n, m, reqs)
        cells.append(cell)
        print(f"n={n:>5} m={m:>2} reqs={reqs:>6}: blind "
              f"{cell['serving_cost_blind']:.0f} vs aware "
              f"{cell['serving_cost_aware']:.0f} "
              f"({cell['aware_saving_pct']}% saved)  "
              f"{cell['throughput_rps']} req/s p99 "
              f"{cell['latency_p99_ms']}ms  traces "
              f"{cell['forward_traces']}  parity mismatches "
              f"{cell['parity_mismatches']}/{cell['parity_sample']}")
    _merge(args.out, cells)
    repl_cells = []
    for kind, n, m, reqs in repl_grid:
        cell = run_replication_cell(kind, n, m, reqs)
        repl_cells.append(cell)
        print(f"{kind:>7} n={n:>5} m={m:>2}: blind "
              f"{cell['serving_cost_blind']:.0f} aware "
              f"{cell['serving_cost_aware']:.0f} replicated "
              f"{cell['serving_cost_replicated']:.0f} "
              f"({cell['replicas']} replicas, "
              f"{cell['ratio_vs_best_move']}x vs best move-only)  "
              f"replica rows {cell['ego_rows_replica_hit']}  parity "
              f"{cell['parity_mismatches']}/{cell['parity_sample']}  "
              f"patch-identical {cell['patch_bit_identical']}")
    _merge(args.out, repl_cells, key="replication_cells")

    if args.fail_on_mismatch:
        bad = _verify(cells) + _verify_replication(repl_cells)
        if bad:
            print("SERVING GATE FAILURES:")
            for b in bad:
                print("  " + b)
            return 1
        print("serving gate: oracle parity exact, traffic-aware layout "
              "serves cheaper, replication beats move-only")
    return 0


def check_parity(ref_path: str = "BENCH_layout.json") -> int:
    """Re-run the quick cell and fail on drift vs the committed numbers.

    Gated quantities are integers / exact orderings: oracle-parity
    mismatch counts (must be 0), the aware<=blind and
    replicated<=aware<=blind orderings, the ego row ledgers
    (local+replica+hit+fetched is fixed by graph, stream and layout), the
    replica count, and replica-patch bit-identity — wall-clock never
    gates."""
    with open(ref_path) as f:
        ref = json.load(f)
    ref_cells = {(c["n"], c["m"]): c for c in ref.get("serving_cells", [])}
    if not ref_cells:
        print(f"no serving_cells committed in {ref_path}; failing")
        return 1
    got = run_serving_cell(800, 6, 4000)
    bad = _verify([got])
    r = ref_cells.get((800, 6))
    if r is None:
        bad.append("committed file lacks the (n=800, m=6) cell")
    else:
        total = (got["ego_rows_local"] + got["ego_rows_cache_hit"]
                 + got["ego_rows_fetched"])
        ref_total = (r["ego_rows_local"] + r["ego_rows_cache_hit"]
                     + r["ego_rows_fetched"])
        if total != ref_total:
            bad.append(f"ego row ledger {total} != committed {ref_total} "
                       f"(extraction or layout drift)")
    ref_repl = {(c["kind"], c["n"], c["m"]): c
                for c in ref.get("replication_cells", [])}
    if not ref_repl:
        bad.append(f"no replication_cells committed in {ref_path}")
    else:
        got_r = run_replication_cell("scatter", 800, 8, 4000)
        bad += _verify_replication([got_r])
        rr = ref_repl.get(("scatter", 800, 8))
        if rr is None:
            bad.append("committed file lacks the (scatter, n=800, m=8) "
                       "replication cell")
        else:
            for f in ("replicas", "ego_rows_replica_hit"):
                if got_r[f] != rr[f]:
                    bad.append(f"replication {f} {got_r[f]} != committed "
                               f"{rr[f]} (overlay or layout drift)")
    if bad:
        print(f"SERVING PARITY CHECK FAILED against {ref_path}")
        for b in bad:
            print("  " + b)
        return 1
    print(f"serving parity OK vs {ref_path}")
    return 0


def run(full: bool = False, smoke: bool = False) -> int:
    argv = []
    if smoke or not full:
        argv.append("--quick")
    if smoke:
        argv += ["--out", "BENCH_layout.smoke.json", "--fail-on-mismatch"]
    elif not full:
        argv += ["--out", "BENCH_layout.quick.json"]
    return main(argv)


if __name__ == "__main__":
    sys.exit(main())
