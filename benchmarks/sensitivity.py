"""Fig. 19/20: sensitivity of R (GLAD-S convergence patience) and theta
(GLAD-A SLA) — converged cost + iterations vs R; average cost + GLAD-S
invocations vs theta."""
from __future__ import annotations

import numpy as np

from benchmarks.common import cost_model, dataset, emit, fleet
from repro.core import workload_for
from repro.core.evolution import apply_delta, evolution_trace
from repro.core.glad_a import GladA
from repro.core.glad_s import glad_s


def run_r(full: bool = False, servers: int = 60,
          Rs=(1, 2, 3, 6, 12, 24, 48)):
    rows = []
    for ds in ("siot", "yelp"):
        g = dataset(ds, full)
        net = fleet(g, servers)
        cm = cost_model(g, net, "gat", ds)
        for R in Rs:
            res = glad_s(cm, R=R, seed=0)
            rows.append([ds, R, round(res.cost, 2), res.iterations])
    return emit(rows, ["dataset", "R", "converged_cost", "iterations"])


def run_theta(full: bool = False, servers: int = 10, slots: int = 30,
              thetas=(0.1, 1.0, 10.0, 60.0)):
    rows = []
    for ds in ("siot", "yelp"):
        g0 = dataset(ds, full)
        net = fleet(g0, servers)
        in_dim = 52 if ds == "siot" else 100
        gnn = workload_for("gat", in_dim)
        trace = evolution_trace(g0, slots, pct_links=0.01, seed=7)
        for theta in thetas:
            sched = GladA(net, gnn, g0, theta=theta, R=3, seed=0)
            cur = g0
            costs = []
            for delta in trace:
                cur = apply_delta(cur, delta)
                costs.append(sched.step(cur).cost)
            n_s = sum(1 for r in sched.records[1:] if r.algorithm == "glad-s")
            rows.append([ds, theta, round(float(np.mean(costs)), 2), n_s])
    return emit(rows, ["dataset", "theta", "avg_cost", "glad_s_invocations"])


def run(full: bool = False):
    run_r(full)
    return run_theta(full)


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
