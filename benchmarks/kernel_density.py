"""Ablation: GLAD layout quality as an MXU-efficiency knob.

The block-sparse SpMM kernel (kernels/gnn_aggregate.py) stores only nonempty
(bm, bk) link blocks; its MXU utilization is the nonzero density within
stored blocks and its HBM traffic scales with the stored-block count.
Ordering vertices by (GLAD partition, degree) concentrates links into fewer,
denser blocks than a random order — the paper's C_T objective doubles as a
kernel-efficiency objective.

  PYTHONPATH=src python -m benchmarks.kernel_density
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, emit
from repro.core import data_partition, workload_for
from repro.gnn.models import directed_edges


def _relabel(edges: np.ndarray, order: np.ndarray) -> np.ndarray:
    inv = np.empty_like(order)
    inv[order] = np.arange(len(order))
    return inv[edges]


def run(full: bool = False, parts: int = 8, bm: int = 8, bk: int = 128):
    g = dataset("siot", full)
    sd = directed_edges(g.edges)
    rng = np.random.default_rng(0)

    orders = {"original": np.arange(g.n),
              "random": rng.permutation(g.n)}
    part = data_partition(g, workload_for("gcn", 52), num_parts=parts, seed=0)
    deg = g.degrees
    # GLAD order: group by partition, heavy vertices first within a group.
    orders["glad+degree"] = np.lexsort((-deg, part.assign))

    rows = []
    for name, order in orders.items():
        e2 = _relabel(sd, np.asarray(order))
        # True block occupancy (the padded kernel layout also pads rows to
        # the max blocks-per-row; what GLAD changes is the NONEMPTY count
        # and the worst row, which set HBM traffic and grid size).
        ib = e2[:, 1] // bm
        jb = e2[:, 0] // bk
        keys = np.unique(ib.astype(np.int64) * (g.n // bk + 2) + jb)
        nonempty = len(keys)
        blocks_per_row = np.bincount(
            np.unique(np.stack([ib, jb], 1), axis=0)[:, 0],
            minlength=(g.n + bm - 1) // bm)
        max_row = int(blocks_per_row.max())
        density = len(e2) / (nonempty * bm * bk)
        padded = blocks_per_row.shape[0] * max_row
        rows.append([name, nonempty, max_row, padded,
                     round(density, 5),
                     round(padded * bm * bk * 4 / 2**20, 2)])
    return emit(rows, ["ordering", "nonempty_blocks", "max_blocks_per_row",
                       "padded_grid_blocks", "nnz_density",
                       "padded_bytes_MB"])


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
