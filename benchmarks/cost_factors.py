"""Fig. 10-13: the four cost factors (C_U, C_P, C_T, C_M) of GAT over Yelp
with a varying number of edge servers, normalized to Random@10's C_U."""
from __future__ import annotations


from benchmarks.common import cost_model, dataset, emit, fleet
from repro.core.baselines import greedy_layout, random_layout
from repro.core.glad_s import glad_s


def run(full: bool = False, server_counts=(10, 20, 30, 40, 50, 60)):
    g = dataset("yelp", full)
    rows = []
    norm = None
    for m in server_counts:
        net = fleet(g, m)
        cm = cost_model(g, net, "gat", "yelp")
        layouts = {
            "random": random_layout(cm, seed=0),
            "greedy": greedy_layout(cm),
            "glad": glad_s(cm, R=3, seed=0).assign,
        }
        for name, assign in layouts.items():
            f = cm.factors(assign)
            if norm is None:
                norm = f["C_U"] if name == "random" else None
            if norm is None:
                norm = 1.0
            rows.append([m, name] + [round(f[k] / norm, 4)
                                     for k in ("C_U", "C_P", "C_T", "C_M")])
    return emit(rows, ["servers", "layout", "C_U", "C_P", "C_T", "C_M"])


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
