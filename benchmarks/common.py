"""Shared benchmark setup mirroring the paper's Sec. VI-A methodology.

Default sizes are scaled for a 1-core CI box; pass --full for the paper's
8001/33509 (SIoT) and 3912/4677 (Yelp) scales.  R defaults to 3 (the paper's
own default); fleet is the Table-II A/B/C mix in equal proportion.
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np

from repro.core import CostModel, workload_for
from repro.core.baselines import greedy_layout, random_layout
from repro.core.glad_s import glad_s
from repro.graphs import build_edge_network, synthetic_siot, synthetic_yelp

FULL_SIZES = {"siot": (8001, 33509, 52), "yelp": (3912, 4677, 100)}
CI_SIZES = {"siot": (1600, 6700, 52), "yelp": (1000, 1200, 100)}


def dataset(name: str, full: bool = False):
    n, e, d = (FULL_SIZES if full else CI_SIZES)[name]
    if name == "siot":
        return synthetic_siot(n=n, target_links=e, feat_dim=d)
    return synthetic_yelp(n=n, target_links=e, feat_dim=d)


def fleet(graph, servers: int, seed: int = 0):
    return build_edge_network(graph, servers, seed=seed)


def cost_model(graph, net, model: str, name: str):
    in_dim = 52 if name == "siot" else 100
    return CostModel(net, graph, workload_for(model, in_dim))


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def run_layouts(cm, seeds=(0, 1, 2), R=None):
    """Random / Greedy / GLAD-S triple, averaged over seeds (paper: 20).
    R=None -> the exhaustive |D|(|D|-1)/2 setting of Sec. IV-B (the quality
    configuration behind Fig. 8/9); the online benches use R=3."""
    rand = float(np.mean([cm.total(random_layout(cm, seed=s)) for s in seeds]))
    greedy = cm.total(greedy_layout(cm))
    glad_costs = []
    wall = 0.0
    for s in seeds:
        res = glad_s(cm, R=R, seed=s)
        glad_costs.append(res.cost)
        wall += res.wall_time_s
    return {
        "random": rand,
        "greedy": float(greedy),
        "glad": float(np.mean(glad_costs)),
        "glad_wall_s": wall / len(seeds),
    }


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
